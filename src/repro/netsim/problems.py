"""Canonical test/benchmark problem for the cluster simulator.

One definition of the quadratic consensus problem f_i(x) = ||x - c_i||^2
shared by tests/test_netsim.py, tests/test_netsim_engine.py and
benchmarks/bench_netsim.py -- the same silently-diverging-copies argument
that moved the default stepsize into `core.dda.stepsize_sqrt` applies to
what the bench gates vs what the tests assert.

The problem is consensus-essential with a closed-form optimum: the common
+offset keeps ||mean(c)|| large so the x0 = 0 optimality gap dominates the
irreducible spread term mean ||c_i - cbar||^2, and
F(x) = ||x - cbar||^2 + spread gives an O(d) batch-capable evaluation.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

__all__ = ["quadratic_consensus"]


def quadratic_consensus(n: int, d: int, seed: int = 0,
                        batchable: bool = False
                        ) -> tuple[np.ndarray, Callable, Callable]:
    """Returns (centers, grad_fn, eval_fn) for the n-node quadratic.

    grad_fn follows the NetSimulator convention `(i, x_i, t)` and is
    batchable as-is (numpy fancy indexing broadcasts over stacked inputs).
    With `batchable=False` eval_fn is the per-point mean-of-squares form
    (O(n d) per call, NOT batch-safe: on a stacked input it silently
    broadcasts to a wrong scalar, which is exactly what the engines'
    bitwise probe must reject). With `batchable=True` it is the closed
    form, accepting either one point (d,) or a stack (b, d).
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n, d)) * 2.0 + 3.0
    cbar = centers.mean(axis=0)
    spread = float(np.mean(np.sum(centers ** 2, axis=1)) - np.sum(cbar ** 2))

    def grad_fn(i, x, t):
        return 2.0 * (x - centers[i])

    if batchable:
        def eval_fn(x):
            x = np.asarray(x)
            if x.ndim == 1:
                return float(np.sum((x - cbar) ** 2) + spread)
            return np.sum((x - cbar) ** 2, axis=-1) + spread
    else:
        def eval_fn(x):
            return float(np.mean(np.sum((x[None] - centers) ** 2, axis=1)))

    return centers, grad_fn, eval_fn
