"""FaultPlan: the frozen, JSON-exact spec for deterministic fault injection.

A plan is data, not behavior: explicit `FaultEvent`s pin crashes, restarts,
joins, leaves, and link partitions/heals to exact sim times, while the
stochastic knobs (exponential MTBF crashes, flapping links) describe renewal
processes that `repro.faults.runtime.FaultRuntime` drives from the plan's
OWN seeded RNG stream -- the optimization stream (`NetSimulator(seed=...)`)
never sees a fault-related draw, so turning faults on cannot silently
re-randomize losses or jitter.

Plans resolve through the `faultplans` registry exactly like every other
`ExperimentSpec` component:

    "faults": {"kind": "churn", "params": {"frac": 0.2, "period": 2.0,
                                           "downtime": 0.5, "cycles": 4}}

The builders take the problem size `n` from the runner context so manifests
stay size-agnostic; explicit plans validate node ids against it.
"""

from __future__ import annotations

import dataclasses
import math

from repro.experiments.registry import Registry

__all__ = ["FaultEvent", "FaultPlan", "faultplans"]

_ACTIONS = ("crash", "restart", "join", "leave", "partition", "heal")
_NODE_ACTIONS = ("crash", "restart", "join", "leave")
_RESTORES = ("warm", "checkpoint")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: `action` fires at sim time `time`.

    `node` targets crash/restart/join/leave; `group` names one side of a
    partition cut (every link crossing the cut blocks, both directions,
    until the next `heal`)."""

    time: float
    action: str
    node: int = -1
    group: tuple[int, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "time", float(self.time))
        object.__setattr__(self, "node", int(self.node))
        object.__setattr__(self, "group",
                           tuple(int(g) for g in self.group))
        if self.action not in _ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r} "
                             f"(one of {_ACTIONS})")
        if not (math.isfinite(self.time) and self.time >= 0.0):
            raise ValueError(f"fault time must be finite and >= 0, "
                             f"got {self.time}")
        if self.action in _NODE_ACTIONS and self.node < 0:
            raise ValueError(f"{self.action!r} needs a node id")
        if self.action == "partition" and not self.group:
            raise ValueError("'partition' needs a non-empty group")

    def to_dict(self) -> dict:
        d = {"time": self.time, "action": self.action}
        if self.action in _NODE_ACTIONS:
            d["node"] = self.node
        if self.action == "partition":
            d["group"] = list(self.group)
        return d


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Everything the fault runtime needs, frozen and JSON round-trippable.

    Deterministic layer: `events`. Stochastic layer: `crash_mtbf` /
    `crash_mttr` draw exponential crash/repair dwell times (capped at
    `max_crashes` total when > 0), `flap_links` toggle up/down with
    `flap_mtbf` / `flap_mttr` dwells; all draws come from
    `default_rng(seed)` and nothing else touches that stream.

    Recovery: `restore="warm"` restarts a node from the survivors'
    consensus average (`elastic.rescale_state` semantics);
    `restore="checkpoint"` resumes from the latest periodic in-sim
    snapshot (taken every `checkpoint_every` sim-time units; persisted
    through `checkpoint.CheckpointManager` when `checkpoint_dir` is set,
    otherwise held in memory)."""

    events: tuple[FaultEvent, ...] = ()
    crash_mtbf: float = 0.0
    crash_mttr: float = 0.0
    max_crashes: int = 0
    flap_links: tuple[tuple[int, int], ...] = ()
    flap_mtbf: float = 0.0
    flap_mttr: float = 0.0
    restore: str = "warm"
    checkpoint_every: float = 0.0
    checkpoint_dir: str | None = None
    checkpoint_keep: int = 3
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(
            ev if isinstance(ev, FaultEvent) else FaultEvent(**ev)
            for ev in self.events))
        object.__setattr__(self, "flap_links", tuple(
            (int(a), int(b)) for a, b in self.flap_links))
        if self.restore not in _RESTORES:
            raise ValueError(f"restore must be one of {_RESTORES}, "
                             f"got {self.restore!r}")
        for name in ("crash_mtbf", "crash_mttr", "flap_mtbf", "flap_mttr",
                     "checkpoint_every"):
            v = getattr(self, name)
            if not (math.isfinite(v) and v >= 0.0):
                raise ValueError(f"{name} must be finite and >= 0, got {v}")
        if self.max_crashes < 0:
            raise ValueError("max_crashes must be >= 0 (0 = uncapped)")
        if self.flap_links and not (self.flap_mtbf > 0.0
                                    and self.flap_mttr > 0.0):
            raise ValueError("flap_links need flap_mtbf > 0 and "
                             "flap_mttr > 0")
        if self.restore == "checkpoint" and self.checkpoint_every <= 0.0:
            raise ValueError("restore='checkpoint' needs "
                             "checkpoint_every > 0")
        if self.checkpoint_keep < 1:
            raise ValueError("checkpoint_keep must be >= 1")
        for a, b in self.flap_links:
            if a == b or a < 0 or b < 0:
                raise ValueError(f"bad flap link ({a}, {b})")

    def validate_for(self, n: int) -> "FaultPlan":
        """Check every node id against the problem size; returns self."""
        for ev in self.events:
            if ev.action in _NODE_ACTIONS and not 0 <= ev.node < n:
                raise ValueError(f"fault event node {ev.node} out of range "
                                 f"for n={n}")
            for g in ev.group:
                if not 0 <= g < n:
                    raise ValueError(f"partition group id {g} out of range "
                                     f"for n={n}")
        for a, b in self.flap_links:
            if a >= n or b >= n:
                raise ValueError(f"flap link ({a}, {b}) out of range "
                                 f"for n={n}")
        return self

    def to_dict(self) -> dict:
        return {"events": [ev.to_dict() for ev in self.events],
                "crash_mtbf": self.crash_mtbf,
                "crash_mttr": self.crash_mttr,
                "max_crashes": self.max_crashes,
                "flap_links": [list(l) for l in self.flap_links],
                "flap_mtbf": self.flap_mtbf,
                "flap_mttr": self.flap_mttr,
                "restore": self.restore,
                "checkpoint_every": self.checkpoint_every,
                "checkpoint_dir": self.checkpoint_dir,
                "checkpoint_keep": self.checkpoint_keep,
                "seed": self.seed}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FaultPlan keys: {sorted(unknown)}")
        if "flap_links" in d:
            d["flap_links"] = tuple(tuple(l) for l in d["flap_links"])
        return cls(**d)


faultplans = Registry("faultplan")


@faultplans.register("plan")
def _build_plan(n: int, events=(), **kw) -> FaultPlan:
    """Explicit FaultEvent list plus stochastic crash/flap knobs."""
    return FaultPlan(events=tuple(events), **kw).validate_for(n)


@faultplans.register("churn")
def _build_churn(n: int, frac: float = 0.2, period: float = 2.0,
                 downtime: float = 0.5, start: float = 1.0, cycles: int = 4,
                 **kw) -> FaultPlan:
    """Preset: every `period` sim-time units starting at `start`, crash the
    next `ceil(frac * n)` nodes (round-robin over the cluster) and restart
    them `downtime` later. Size-agnostic: `n` comes from the runner."""
    if not 0.0 < frac <= 1.0:
        raise ValueError(f"frac must be in (0, 1], got {frac}")
    if not 0.0 < downtime < period:
        raise ValueError("need 0 < downtime < period so each wave restarts "
                         "before the next one crashes")
    if cycles < 1:
        raise ValueError("cycles must be >= 1")
    m = max(1, math.ceil(frac * n))
    if m >= n:
        raise ValueError(f"churn frac={frac} would crash all {n} nodes at "
                         "once; keep frac < 1 - 1/n")
    events = []
    for c in range(cycles):
        t = start + c * period
        for j in range(m):
            node = (c * m + j) % n
            events.append(FaultEvent(time=t, action="crash", node=node))
            events.append(FaultEvent(time=t + downtime, action="restart",
                                     node=node))
    return FaultPlan(events=tuple(events), **kw).validate_for(n)
