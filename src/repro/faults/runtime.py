"""FaultRuntime: executes a `FaultPlan` inside both netsim engines.

The runtime is engine-agnostic: engines hand it the event queue and a small
adapter surface (`fault_state`, `fault_apply_node`, `fault_clear_inbox`,
`fault_activate`, `fault_deactivate`, `fault_splice_graph`,
`fault_next_comm`, `fault_notify_membership`, `fault_notify_heal`) and the
runtime keeps ALL fault bookkeeping -- alive/member masks, step
generations, the blocked-link matrix, counters, the fault RNG -- in shared
code, so the object and vectorized engines stay bit-identical under every
plan by construction: every handler runs at the same sim time in the same
queue order on both engines, mutates the same numpy state, and consumes
the same draws from the plan's private RNG stream.

Semantics:

- **crash**: the node stops stepping (its pending step event goes stale via
  a per-node generation counter), its inbox entries vanish on BOTH sides so
  neighbors fold the missing weight back into their self-loop -- exactly
  `fault_tolerance.degraded_matrix`'s stale-mix semantics -- and messages
  that arrive while it is down are silently dropped. Messages still in
  flight when the crash fires are only dropped if they land during the
  downtime window: network asynchrony means the wire cannot know the
  sender died, and DDA's stale-stamp mixing tolerates a late pre-crash
  packet by design.
- **restart**: the node resumes from the latest in-sim checkpoint
  (`restore="checkpoint"`) or warm-starts from the survivors' consensus
  average (`restore="warm"`, the `elastic.rescale_state` rule: mean state,
  min iteration counter). Its next comm step is re-derived from the live
  schedule so adaptive retunes that happened during the downtime apply.
- **leave / join**: membership changes; the live topology is replaced by a
  freshly built regular expander over the current members (embedded into
  the original n with identity self-loops for non-members, so every mixing
  row stays stochastic) and spliced into the network's `GraphSequence`.
  The controller is told about the SUB-graph -- feeding it the embedded
  full-size graph would poison h_opt with the identity rows' lambda2.
- **partition / heal**: every directed link crossing the cut blocks at
  SEND time (before any loss/jitter draw, so the optimization RNG stream
  is untouched); heal unblocks everything and nudges the controller to
  retune immediately against the reconnected topology.
"""

from __future__ import annotations

import numpy as np

from repro.core.graphs import CommGraph, random_regular_expander
from repro.faults.plan import FaultPlan

__all__ = ["FaultRuntime", "embed_subgraph"]


def embed_subgraph(sub: CommGraph, n: int, members: np.ndarray) -> CommGraph:
    """Lift an m-node CommGraph onto n nodes: members wire through `sub`,
    non-members keep identity self-loops (perm[i] = i) so every row of the
    mixing matrix stays stochastic and `GraphSequence` splicing is legal."""
    members = np.asarray(members, dtype=np.int64)
    perms = []
    for perm in sub.perms:
        full = np.arange(n, dtype=np.int64)
        full[members] = members[np.asarray(perm, dtype=np.int64)]
        perms.append(tuple(int(v) for v in full))
    return CommGraph(f"{sub.name}_embed{len(members)}", n, tuple(perms),
                     sub.self_weight, sub.edge_weight)


class FaultRuntime:
    """Shared fault machinery both engines drive through `handle()`."""

    def __init__(self, plan: FaultPlan, n: int, tracer=None):
        self.plan = plan
        self.n = n
        self.alive = np.ones(n, dtype=bool)
        self.member = np.ones(n, dtype=bool)
        self.step_gen = np.zeros(n, dtype=np.int64)
        self.blocked = np.zeros((n, n), dtype=bool)
        # the fault stream: ONLY fault handlers draw from it, and handlers
        # fire in identical order on both engines
        self.rng = np.random.default_rng(plan.seed)
        self.crashes = 0
        self.restarts = 0
        self.joins = 0
        self.leaves = 0
        self.downtime_sim = 0.0
        self.partition_epochs = 0
        self.link_flaps = 0
        self.checkpoints = 0
        self.blocked_sends = 0
        self._crash_time: dict[int, float] = {}
        self._part_pairs: set[tuple[int, int]] = set()
        self._flap_down: dict[tuple[int, int], bool] = {}
        self._ckpt: dict | None = None
        self._ckpt_seq = 0
        self._rebuilds = 0
        self._mgr = None
        if plan.checkpoint_every > 0.0 and plan.checkpoint_dir is not None:
            from repro.checkpoint.manager import CheckpointManager
            self._mgr = CheckpointManager(plan.checkpoint_dir,
                                          keep=plan.checkpoint_keep)
        self._tr = tracer if (tracer is not None
                              and getattr(tracer, "detail", False)) else None
        self.eng = None
        self._base_degree = 0

    def bind(self, engine) -> None:
        self.eng = engine
        self._base_degree = engine.net.graph.degree

    def stats(self) -> dict:
        return {"crashes": int(self.crashes),
                "restarts": int(self.restarts),
                "joins": int(self.joins),
                "leaves": int(self.leaves),
                "downtime_sim": float(self.downtime_sim),
                "partition_epochs": int(self.partition_epochs),
                "link_flaps": int(self.link_flaps),
                "checkpoints": int(self.checkpoints),
                "blocked_sends": int(self.blocked_sends)}

    def record_mask(self) -> np.ndarray | None:
        """Rows to include in trace records: live members only (a trace
        point must not average in a crashed node's frozen iterate). None
        when nobody is up -- callers fall back to all rows."""
        m = self.alive & self.member
        return m if (m.any() and not m.all()) else (m if m.any() else None)

    # -- scheduling ----------------------------------------------------------

    def schedule_initial(self, q) -> None:
        """Seed the queue: explicit plan events verbatim, then the first
        renewal draw of each stochastic process in a FIXED order (MTBF
        crash, then flap links in declaration order) so the fault stream is
        consumed identically on both engines."""
        for ev in self.plan.events:
            q.schedule(ev.time, "fault", action=ev.action, node=ev.node,
                       group=ev.group)
        if self.plan.crash_mtbf > 0.0:
            q.schedule(float(self.rng.exponential(self.plan.crash_mtbf)),
                       "fault", action="mtbf")
        for link in self.plan.flap_links:
            q.schedule(float(self.rng.exponential(self.plan.flap_mtbf)),
                       "fault", action="flap", link=link)
        if self.plan.checkpoint_every > 0.0:
            q.schedule(self.plan.checkpoint_every, "fault",
                       action="checkpoint")

    def handle(self, q, data: dict) -> None:
        act = data["action"]
        if act == "crash":
            self._crash(q, data["node"])
        elif act == "restart":
            self._restart(q, data["node"])
        elif act == "join":
            self._join(q, data["node"])
        elif act == "leave":
            self._leave(q, data["node"])
        elif act == "partition":
            self._partition(q, data["group"])
        elif act == "heal":
            self._heal(q)
        elif act == "mtbf":
            self._mtbf(q)
        elif act == "flap":
            self._flap(q, data["link"])
        elif act == "checkpoint":
            self._checkpoint(q)
        else:  # pragma: no cover - plan validation rejects these earlier
            raise ValueError(f"unknown fault action {act!r}")

    # -- node lifecycle ------------------------------------------------------

    def _crash(self, q, j: int) -> None:
        if not (self.alive[j] and self.member[j]):
            return  # already down / not a member: deterministic no-op
        self.alive[j] = False
        self.step_gen[j] += 1
        self._crash_time[j] = q.now
        self.crashes += 1
        self.eng.fault_deactivate(j)
        self.eng.fault_clear_inbox(j)
        self._instant(q, "fault_crash", node=j)

    def _restore_row(self, j: int) -> dict:
        """State a restarting/joining node j resumes with. Checkpoint row
        when asked for and available, else warm start: mean x/xhat/z over
        the live members, min of their iteration counters (re-running a few
        steps is safe; skipping ahead is not). Falls back to j's own frozen
        state when nobody else is up. next_comm is ALWAYS re-derived from
        the live schedule (retunes may have happened during the downtime)."""
        eng = self.eng
        if self.plan.restore == "checkpoint" and self._ckpt is not None:
            c = self._ckpt
            t = int(c["t"][j])
            return {"x": c["x"][j].copy(), "xhat": c["xhat"][j].copy(),
                    "z": c["z"][j].copy(), "t": t,
                    "comm_iters": int(c["comm_iters"][j]),
                    "next_comm": eng.fault_next_comm(t)}
        st = eng.fault_state()
        others = self.alive & self.member
        others[j] = False
        if not others.any():
            t = int(st["t"][j])
            return {"x": st["x"][j], "xhat": st["xhat"][j], "z": st["z"][j],
                    "t": t, "comm_iters": int(st["comm_iters"][j]),
                    "next_comm": eng.fault_next_comm(t)}
        t = int(st["t"][others].min())
        return {"x": st["x"][others].mean(axis=0),
                "xhat": st["xhat"][others].mean(axis=0),
                "z": st["z"][others].mean(axis=0),
                "t": t,
                "comm_iters": int(st["comm_iters"][others].min()),
                "next_comm": eng.fault_next_comm(t)}

    def _restart(self, q, j: int) -> None:
        if self.alive[j] or not self.member[j]:
            return
        row = self._restore_row(j)
        self.alive[j] = True
        self.downtime_sim += q.now - self._crash_time.pop(j, q.now)
        self.restarts += 1
        self.step_gen[j] += 1
        self.eng.fault_apply_node(j, row)
        self.eng.fault_activate(j)
        self._instant(q, "fault_restart", node=j)

    def _leave(self, q, j: int) -> None:
        if not self.member[j]:
            return
        self.member[j] = False
        self.leaves += 1
        self.step_gen[j] += 1
        if self.alive[j]:
            self.alive[j] = False
            self.eng.fault_deactivate(j)
        else:
            # a crashed node that leaves stops accruing downtime: it is
            # gone, not down
            self._crash_time.pop(j, None)
        self.eng.fault_clear_inbox(j)
        self._splice(q)
        self._instant(q, "fault_leave", node=j)

    def _join(self, q, j: int) -> None:
        if self.member[j]:
            return
        row = self._restore_row(j)  # before flipping flags: exclude j
        self.member[j] = True
        self.alive[j] = True
        self.joins += 1
        self.step_gen[j] += 1
        self.eng.fault_apply_node(j, row)
        self._splice(q)  # before activate: busy time uses the new degree
        self.eng.fault_activate(j)
        self._instant(q, "fault_join", node=j)

    def _splice(self, q) -> None:
        """Rebuild the topology over current members and splice it into
        the live GraphSequence (same n, so downstream state shapes hold)."""
        members = np.nonzero(self.member)[0]
        m = len(members)
        if m == 0:
            return  # everyone left; nothing to wire
        k = max(2, (self._base_degree // 2) * 2)
        self._rebuilds += 1
        sub = random_regular_expander(m, k=k,
                                      seed=self.plan.seed + self._rebuilds)
        self.eng.fault_splice_graph(embed_subgraph(sub, self.n, members))
        self.eng.fault_notify_membership(sub, members)

    # -- links ---------------------------------------------------------------

    def _partition(self, q, group) -> None:
        g = {int(x) for x in group}
        other = [i for i in range(self.n) if i not in g]
        for a in g:
            for b in other:
                self._part_pairs.add((a, b))
                self._part_pairs.add((b, a))
        self.partition_epochs += 1
        self._rebuild_blocked()
        self._instant(q, "fault_partition", size=len(g))

    def _heal(self, q) -> None:
        if not self._part_pairs:
            return
        self._part_pairs.clear()
        self._rebuild_blocked()
        self.eng.fault_notify_heal(q.now)
        self._instant(q, "fault_heal")

    def _flap(self, q, link) -> None:
        link = (int(link[0]), int(link[1]))
        down = not self._flap_down.get(link, False)
        self._flap_down[link] = down
        self.link_flaps += 1
        self._rebuild_blocked()
        if self.eng.active > 0:
            mean = self.plan.flap_mttr if down else self.plan.flap_mtbf
            q.schedule_in(float(self.rng.exponential(mean)), "fault",
                          action="flap", link=link)

    def _rebuild_blocked(self) -> None:
        self.blocked[:] = False
        for a, b in self._part_pairs:
            self.blocked[a, b] = True
        for (a, b), down in self._flap_down.items():
            if down:
                self.blocked[a, b] = True
                self.blocked[b, a] = True

    # -- stochastic crashes --------------------------------------------------

    def _mtbf(self, q) -> None:
        plan = self.plan
        pool = np.nonzero(self.alive & self.member)[0]
        if len(pool):  # draw order fixed: victim, repair dwell, next crash
            j = int(pool[self.rng.integers(len(pool))])
            self._crash(q, j)
            if plan.crash_mttr > 0.0:
                q.schedule_in(float(self.rng.exponential(plan.crash_mttr)),
                              "fault", action="restart", node=j)
        if ((plan.max_crashes == 0 or self.crashes < plan.max_crashes)
                and self.eng.active > 0):
            q.schedule_in(float(self.rng.exponential(plan.crash_mtbf)),
                          "fault", action="mtbf")

    # -- checkpoints ---------------------------------------------------------

    def _checkpoint(self, q) -> None:
        snap = self.eng.fault_state()
        self._ckpt = snap
        self._ckpt_seq += 1
        self.checkpoints += 1
        if self._mgr is not None:
            self._mgr.save(self._ckpt_seq, snap,
                           extra={"sim_time": float(q.now)}, blocking=True)
        if self.eng.active > 0:
            q.schedule_in(self.plan.checkpoint_every, "fault",
                          action="checkpoint")

    def _instant(self, q, name: str, **meta) -> None:
        if self._tr is not None:
            self._tr.add_instant(name, t=q.now, track="faults", **meta)
