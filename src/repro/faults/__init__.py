"""repro.faults -- deterministic fault injection for the netsim engines.

A frozen, JSON-exact `FaultPlan` schedules crashes, restarts, joins,
leaves, link partitions and heals at simulation times -- plus seeded
stochastic processes (exponential MTBF crashes, flapping links) driven by
their own RNG stream, so the main simulation RNG and therefore every
fault-free trace is untouched. `FaultRuntime` executes a plan as
first-class simulation events on EITHER netsim engine through a small
adapter surface (`fault_*` methods); both engines stay bit-identical
under every plan (tests/test_faults.py).
"""

from repro.faults.plan import FaultEvent, FaultPlan, faultplans
from repro.faults.runtime import FaultRuntime, embed_subgraph

__all__ = [
    "FaultEvent",
    "FaultPlan",
    "FaultRuntime",
    "embed_subgraph",
    "faultplans",
]
