"""Launch layer: mesh construction, abstract input specs, step factories,
multi-pod dry-run, and the training/serving drivers."""
