"""Training driver: consensus data-parallel LM training with the paper's
communication schedules, checkpoint/restart, and optional straggler
simulation. This is the host loop the examples use; on a real cluster each
pod's process group runs exactly this with the mesh spanning its slice.

The schedule decides per iteration whether to run the cheap `local_step`
(no cross-pod collective) or the `fused_step` (local + consensus mixing) --
the paper's 1/n vs 1/n + kr cost split is directly visible as two compiled
programs.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.graphs import CommGraph, build_graph
from repro.core.schedules import CommSchedule, EveryIteration
from repro.data.pipeline import TokenStream
from repro.launch import specs as sp
from repro.launch.steps import make_consensus_steps, make_train_step
from repro.models import transformer
from repro.models.common import ModelConfig
from repro.optim import Optimizer
from repro.runtime import sharding as shrules

PyTree = Any


@dataclasses.dataclass
class TrainReport:
    steps: int
    losses: list
    comm_rounds: int
    sim_time_units: float
    resumed_from: int | None = None
    # backend-specific observability (dryrun compile stats, wall timings);
    # surfaced as RunResult.extras by the repro.experiments launch backend
    extras: dict = dataclasses.field(default_factory=dict)


def train_consensus_lm(cfg: ModelConfig, optimizer: Optimizer, mesh,
                       *, steps: int = 100,
                       schedule: CommSchedule | None = None,
                       topology: str = "complete",
                       graph: CommGraph | None = None,
                       r_estimate: float = 0.05,
                       batch_per_node: int = 8,
                       seq_len: int = 64,
                       ckpt_dir: str | None = None,
                       ckpt_every: int = 50,
                       seed: int = 0,
                       log_every: int = 10,
                       mix_target: str = "params",
                       dryrun: bool = False,
                       tracer=None) -> TrainReport:
    """Run consensus DP training of `cfg` on `mesh` (axes pod, data, model).

    Returns per-step losses plus the simulated time-unit accounting
    (1/n per iteration + k*r per communication round, paper eq. 9/19).

    `graph` overrides the `topology` name with a prebuilt CommGraph (the
    repro.experiments runner resolves topologies through its registry and
    hands the built graph in; n must equal the mesh's pod-axis size).
    `dryrun` lowers + compiles both step programs (cheap local, fused
    local+mix) and returns after ZERO training steps with the compile
    timings in `extras` -- the CI smoke mode for the launch backend.

    `tracer` (optional `repro.obs.Tracer`) receives host-clock spans per
    training step / compile; the per-step walls and comm flags are also
    returned in `extras["step_walls"]` / `extras["step_comm"]` so the
    experiments runner can quote step-time quantiles without a tracer.
    """
    schedule = schedule or EveryIteration()
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_pods = axis_sizes.get("pod", 1)
    if graph is None:
        graph = build_graph(topology, n_pods)
    elif graph.n != n_pods:
        raise ValueError(f"graph has n={graph.n} but the mesh has "
                         f"{n_pods} pods")
    k = graph.degree

    local, mix, fused = make_consensus_steps(
        cfg, optimizer, graph, mesh,
        moe_groups=max(axis_sizes.get("data", 1), 1) if cfg.moe_experts else 1,
        mix_target=mix_target)

    with shrules.use_rules(shrules.DEFAULT_RULES, mesh):
        # concrete init, pod-stacked
        aparams, pspecs = sp.param_specs(cfg, mesh)
        astate, sspecs = sp.opt_state_specs(optimizer, aparams, pspecs)
        aparams, pspecs = sp.pod_stack(aparams, pspecs, n_pods)
        astate, sspecs = sp.pod_stack(astate, sspecs, n_pods)
        psh = sp.to_shardings(pspecs, mesh)
        ssh = sp.to_shardings(sspecs, mesh)

        def init_all(key):
            def one(k_):
                prm, _ = transformer.init(k_, cfg)
                st = optimizer.init(prm)
                return prm, st
            return jax.vmap(one)(jax.random.split(key, n_pods))

        params, opt_state = jax.jit(
            init_all, out_shardings=(psh, ssh))(jax.random.PRNGKey(seed))

        jit_local = jax.jit(local, in_shardings=(psh, ssh, None),
                            out_shardings=(psh, ssh, None),
                            donate_argnums=(0, 1))
        jit_fused = jax.jit(fused, in_shardings=(psh, ssh, None),
                            out_shardings=(psh, ssh, None),
                            donate_argnums=(0, 1))

        streams = [TokenStream(cfg.vocab_size, seq_len, batch_per_node,
                               node_index=i, num_nodes=n_pods, seed=seed)
                   for i in range(n_pods)]

        # bytes one pod ships per gossip round per link: the mixed payload
        # is the per-pod parameter pytree (mix_target="params"), so the
        # pod-stacked leaves divide by n_pods
        param_bytes = sum(leaf.size * leaf.dtype.itemsize
                          for leaf in jax.tree_util.tree_leaves(params))
        param_bytes_per_pod = param_bytes / max(n_pods, 1)

        if dryrun:
            nexts = [next(s) for s in streams]
            batch = {"tokens": jnp.stack([b["tokens"] for b in nexts]),
                     "labels": jnp.stack([b["labels"] for b in nexts])}
            extras = {"dryrun": True, "n_pods": n_pods, "k": k,
                      "param_bytes": param_bytes_per_pod}
            for name, fn in (("local", jit_local), ("fused", jit_fused)):
                t0 = time.time()
                fn.lower(params, opt_state, batch).compile()
                dt = time.time() - t0
                extras[f"{name}_compile_s"] = round(dt, 2)
                if tracer is not None:
                    tracer.add_host_span(f"compile:{name}",
                                         tracer.now() - dt, dt,
                                         track="launch")
            for s in streams:
                s.close()
            return TrainReport(steps=0, losses=[], comm_rounds=0,
                               sim_time_units=0.0, extras=extras)

        mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        start_step = 0
        resumed = None
        if mgr is not None:
            got = mgr.restore_latest((params, opt_state))
            if got is not None:
                start_step, (params, opt_state), _ = got
                resumed = start_step

        losses = []
        comm_rounds = 0
        sim_time = 0.0
        step_walls: list[float] = []
        step_comm: list[bool] = []
        for t in range(start_step + 1, steps + 1):
            nexts = [next(s) for s in streams]  # disjoint per-pod shards
            batch = {"tokens": jnp.stack([b["tokens"] for b in nexts]),
                     "labels": jnp.stack([b["labels"] for b in nexts])}
            comm = schedule.is_comm_step(t)
            step_fn = jit_fused if comm else jit_local
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            sim_time += 1.0 / n_pods + (k * r_estimate if comm else 0.0)
            comm_rounds += int(comm)
            loss = float(jnp.mean(metrics["loss"]))  # blocks on the step
            wall = time.perf_counter() - t0
            step_walls.append(wall)
            step_comm.append(comm)
            if tracer is not None:
                tracer.add_host_span("fused_step" if comm else "local_step",
                                     tracer.now() - wall, wall,
                                     track="launch", t=t)
            losses.append(loss)
            if log_every and t % log_every == 0:
                print(f"[train] step {t} loss {loss:.4f} "
                      f"comm_rounds {comm_rounds} sim_time {sim_time:.2f}",
                      flush=True)
            if mgr is not None and t % ckpt_every == 0:
                mgr.save(t, (params, opt_state), extra={"step": t})
        if mgr is not None:
            mgr.wait()
        for s in streams:
            s.close()
        return TrainReport(steps=steps, losses=losses,
                           comm_rounds=comm_rounds,
                           sim_time_units=sim_time, resumed_from=resumed,
                           extras={"param_bytes": param_bytes_per_pod,
                                   "step_walls": step_walls,
                                   "step_comm": step_comm})
