"""Step factories: train_step / serve_step, plus the consensus (multi-pod)
wrappers that realize the paper's algorithm at pod scale.

Consensus mode uses partial-manual `jax.shard_map` over the `pod` mesh axis:
inside, each pod runs a standard GSPMD-auto (data=FSDP, model=TP) step on its
own parameter replica; the paper's mixing z <- Pz (or parameter gossip) is a
collective over the manual 'pod' axis. Cheap iterations compile WITHOUT any
cross-pod collective; expensive iterations carry exactly the graph's
ppermutes/all-reduce -- the launcher alternates per the schedule, so the
communication pattern is explicit in each compiled program (never hidden in
traced control flow).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.consensus import tree_mix_collective
from repro.core.graphs import CommGraph
from repro.launch.compat import shard_map
from repro.models import transformer
from repro.models.common import ModelConfig
from repro.optim import Optimizer, OptState

PyTree = Any


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    moe_groups: int = 1, microbatches: int = 1):
    """Pure synchronous step: (params, opt_state, batch) ->
    (params, opt_state, metrics). Gradients are averaged over the full batch
    (GSPMD reduces over the data axis automatically).

    `microbatches` > 1 runs gradient accumulation: the batch is split along
    its leading dim and a scan accumulates fp32 grads, dividing the
    activation working set by the microbatch count (the production lever
    that fits large-model training in HBM; optimizer state and params are
    untouched)."""

    def grad_fn(params, batch):
        return jax.value_and_grad(transformer.loss_fn)(
            params, batch, cfg, moe_groups)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def resh(a):
                return a.reshape((microbatches, a.shape[0] // microbatches)
                                 + a.shape[1:])
            mb = jax.tree.map(resh, batch)
            zero = jax.tree.map(
                lambda p_: jnp.zeros(p_.shape, jnp.float32), params)

            def acc_fn(carry, mbatch):
                loss_acc, g_acc = carry
                loss, g = grad_fn(params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), zero), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        new_params, new_state = optimizer.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ModelConfig, moe_groups: int = 1):
    """Forward-only (inference prefill): returns last-position logits."""

    def prefill_step(params, batch):
        logits = transformer.forward(params, batch["tokens"], cfg,
                                     enc=batch.get("enc"),
                                     moe_groups=moe_groups)
        return logits[:, -1, :]

    return prefill_step


def make_serve_step(cfg: ModelConfig, moe_groups: int = 1):
    """One-token decode: (params, cache, tokens, pos) -> (logits, cache)."""

    def serve_step(params, cache, tokens, pos):
        return transformer.decode_step(params, cache, tokens, pos, cfg,
                                       moe_groups=moe_groups)

    return serve_step


# ---------------------------------------------------------------------------
# Consensus (multi-pod) wrappers -- the paper's technique as a feature
# ---------------------------------------------------------------------------


def _pod_spec(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda _: P("pod"), tree)


def make_consensus_steps(cfg: ModelConfig, optimizer: Optimizer,
                         graph: CommGraph, mesh,
                         moe_groups: int = 1,
                         mix_target: str = "params",
                         microbatches: int = 1):
    """Returns (local_step, mix_step, fused_step) for consensus training.

    ALL state (params, every optimizer leaf including the step counter)
    carries a leading pod-replica dim of size graph.n = number of pods,
    sharded P('pod', ...). `mix_target` selects WHAT the consensus averages:
      "params" -- gossip parameter averaging (consensus-SGD; section VI mode)
      "z"      -- faithful DDA: mix the dual (accumulated-gradient) state
                  held by the dual_averaging optimizer.

    local_step: one optimizer step per pod on its own data shard; NO
      cross-pod communication (the paper's cheap iteration, cost 1/n).
      Realized as jax.vmap(inner, spmd_axis_name='pod'): the vmap batching
      rule prepends 'pod' to every internal sharding constraint, so each pod
      runs FSDP+TP over (data, model) on its own replica.
    mix_step: consensus mixing only (the communication half of an expensive
      iteration, cost kr) -- a pod-manual shard_map whose body is the
      graph's ppermutes/all-reduce + weighted accumulation, nothing else.
    fused_step: local + mix in one program (expensive iteration, 1/n + kr);
      mixing is expressed as the doubly-stochastic P einsum over the pod
      dim, which GSPMD partitions into cross-pod collectives.
    """
    inner = make_train_step(cfg, optimizer, moe_groups,
                            microbatches=microbatches)
    local = jax.vmap(inner, spmd_axis_name="pod")
    Pmat = jnp.asarray(graph.mixing_matrix(), jnp.float32)

    def _dense_mix(tree):
        return jax.tree.map(
            lambda a: jnp.einsum("pq,q...->p...", Pmat,
                                 a.astype(jnp.float32)).astype(a.dtype),
            tree)

    def mix_body(params, opt_state):
        sq = lambda t: jax.tree.map(lambda x: x[0], t)
        unsq = lambda t: jax.tree.map(lambda x: x[None], t)
        if mix_target == "params":
            mixed = tree_mix_collective(sq(params), graph, "pod")
            return unsq(mixed), opt_state
        mixed_z = tree_mix_collective(sq(opt_state.inner["z"]), graph, "pod")
        return params, OptState(opt_state.step, {"z": unsq(mixed_z)})

    mix = shard_map(mix_body, mesh=mesh,
                    in_specs=(P("pod"), P("pod")),
                    out_specs=(P("pod"), P("pod")),
                    axis_names={"pod"}, check_vma=False)

    def fused_step(params, opt_state, batch):
        params, opt_state, metrics = local(params, opt_state, batch)
        if mix_target == "params":
            params = _dense_mix(params)
        else:
            opt_state = OptState(opt_state.step,
                                 {"z": _dense_mix(opt_state.inner["z"])})
        return params, opt_state, metrics

    return local, mix, fused_step
