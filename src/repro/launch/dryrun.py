import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on the production mesh with placeholder devices; record
memory_analysis / cost_analysis / collective bytes for the roofline.

The two lines above MUST precede every other import (jax locks the device
count on first init). Run as:

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]

Outputs one JSON per cell under results/dryrun/.
"""

import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeCell
from repro.core.graphs import complete_graph
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_consensus_steps, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models import registry
from repro.optim import adamw, cosine_lr
from repro.runtime import sharding as shrules

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# HLO collective ops whose operand bytes count toward the collective term.
_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s32|u32|s8|u8|s64|u64|pred|s16|u16)"
                       r"\[([0-9,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "s64": 8, "u64": 8, "pred": 1, "s16": 2, "u16": 2}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum output-shape bytes of every collective op in the (SPMD-partitioned)
    HLO. Shapes in post-SPMD HLO are per-device; we report per-device bytes
    crossing links. Returns totals keyed by op kind."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "= " not in line:
            continue
        kind = m.group(1).lower()
        rhs = line.split("= ", 1)[1]
        dm = _SHAPE_RE.search(rhs)  # first shape = op output (per-device)
        if dm is None:
            continue
        dims = dm.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out[kind] = out.get(kind, 0.0) + n * _BYTES[dm.group(1)]
    return out


def _cost(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _memory(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
        return {k: float(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def dryrun_cell(arch: str, cell: ShapeCell, multi_pod: bool,
                *, save: bool = True, donate: bool = True,
                verbose: bool = True, cfg_override=None) -> dict:
    """Lower + compile one (arch, shape, mesh) cell; return the record."""
    cfg = cfg_override or registry.get_config(arch, "full")
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    data_size = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]
    moe_groups = data_size if cfg.moe_experts else 1
    rec = {"arch": arch, "shape": cell.name, "mesh": mesh_name,
           "kind": cell.kind, "seq_len": cell.seq_len,
           "global_batch": cell.global_batch}
    t0 = time.time()

    optimizer = adamw(cosine_lr(3e-4, 10000),
                      moment_dtype=(jnp.bfloat16 if cfg.opt_moments_bf16
                                    else jnp.float32))
    with shrules.use_rules(shrules.DEFAULT_RULES, mesh):
        if cell.kind == "train":
            consensus = multi_pod
            params, pspecs = sp.param_specs(cfg, mesh)
            state, sspecs = sp.opt_state_specs(optimizer, params, pspecs)
            batch, bspecs = sp.batch_specs(cfg, cell, mesh,
                                           consensus=consensus)
            if consensus:
                n_pods = dict(zip(mesh.axis_names,
                                  mesh.devices.shape))["pod"]
                params, pspecs = sp.pod_stack(params, pspecs, n_pods)
                state, sspecs = sp.pod_stack(state, sspecs, n_pods)
                graph = complete_graph(n_pods)
                _, _, step = make_consensus_steps(
                    cfg, optimizer, graph, mesh, moe_groups=moe_groups,
                    microbatches=cfg.train_microbatches)
            else:
                step = make_train_step(cfg, optimizer, moe_groups=moe_groups,
                                       microbatches=cfg.train_microbatches)
            args = (params, state, batch)
            in_sh = sp.to_shardings((pspecs, sspecs, bspecs), mesh)
            jitted = jax.jit(
                step, in_shardings=in_sh,
                donate_argnums=(0, 1) if donate else ())
        elif cell.kind == "prefill":
            params, pspecs = sp.param_specs(cfg, mesh)
            batch, bspecs = sp.batch_specs(cfg, cell, mesh, consensus=False)
            step = make_prefill_step(cfg, moe_groups=moe_groups)
            args = (params, batch)
            in_sh = sp.to_shardings((pspecs, bspecs), mesh)
            jitted = jax.jit(step, in_shardings=in_sh)
        else:  # decode
            params, pspecs = sp.param_specs(cfg, mesh)
            cache, cspecs = sp.cache_specs(cfg, cell, mesh)
            toks, tspecs = sp.decode_token_specs(cell, mesh)
            step = make_serve_step(cfg, moe_groups=1)
            args = (params, cache, toks["tokens"], toks["pos"])
            in_sh = sp.to_shardings(
                (pspecs, cspecs, tspecs["tokens"], tspecs["pos"]), mesh)
            jitted = jax.jit(step, in_shardings=in_sh,
                             donate_argnums=(1,) if donate else ())

        with mesh:
            lowered = jitted.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)

    rec["memory"] = _memory(compiled)
    rec["cost"] = _cost(compiled)
    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo)
    rec["hlo_collective_op_counts"] = {
        k: hlo.count(f" {k}") for k in
        ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")}
    n_dev = mesh.devices.size
    arg_b = rec["memory"].get("argument_size_in_bytes", 0.0)
    tmp_b = rec["memory"].get("temp_size_in_bytes", 0.0)
    out_b = rec["memory"].get("output_size_in_bytes", 0.0)
    alias_b = rec["memory"].get("alias_size_in_bytes", 0.0)
    rec["bytes_per_device"] = arg_b + tmp_b + max(out_b - alias_b, 0.0)
    rec["devices"] = n_dev
    if verbose:
        print(f"[dryrun] {arch} {cell.name} {mesh_name}: "
              f"lower {rec['lower_s']}s compile {rec['compile_s']}s  "
              f"mem/dev {(rec['bytes_per_device'])/2**30:.2f} GiB  "
              f"flops {rec['cost'].get('flops', 0):.3g}", flush=True)
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        fname = RESULTS / f"{arch}__{cell.name}__{mesh_name}.json"
        fname.write_text(json.dumps(rec, indent=1))
    return rec


def dryrun_cell_with_cfg(arch: str, cfg, cell: ShapeCell, multi_pod: bool,
                         *, save: bool = False, verbose: bool = False) -> dict:
    """Probe variant: compile `cell` under an explicit (modified) config --
    used by benchmarks/roofline.py for per-layer cost probes."""
    return dryrun_cell(arch, cell, multi_pod, save=save, verbose=verbose,
                       cfg_override=cfg)


def iter_cells(multi_pod_only=False, arch_filter=None, shape_filter=None):
    for arch in registry.ARCH_IDS:
        if arch_filter and arch != arch_filter:
            continue
        for cell in registry.get_shapes(arch).values():
            if shape_filter and cell.name != shape_filter:
                continue
            if cell.skip:
                yield arch, cell, None
                continue
            meshes = [True] if multi_pod_only else [False, True]
            for mp in meshes:
                yield arch, cell, mp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args(argv)

    failures = []
    for arch, cell, mp in iter_cells(args.multi_pod_only, args.arch,
                                     args.shape):
        if mp is None:
            print(f"[dryrun] SKIP {arch} {cell.name}: {cell.skip}")
            continue
        if args.single_pod_only and mp:
            continue
        try:
            dryrun_cell(arch, cell, mp, save=not args.no_save)
        except Exception:
            failures.append((arch, cell.name, mp))
            traceback.print_exc()
    if failures:
        print(f"[dryrun] FAILURES: {failures}")
        return 1
    print("[dryrun] all requested cells compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
