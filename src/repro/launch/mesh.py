"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis carries
the paper's consensus graph (slow DCN links between pods -- exactly the
high-r regime the paper analyzes).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests run on one
CPU device).
"""

from __future__ import annotations

import jax

from repro.launch.compat import make_mesh_auto


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """General mesh for tests/examples (e.g. (2,2,2) on 8 host devices)."""
    return make_mesh_auto(shape, axes)


def mesh_shape(mesh: jax.sharding.Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def num_pods(mesh: jax.sharding.Mesh) -> int:
    return mesh_shape(mesh).get("pod", 1)
