"""jax API compatibility for the launcher.

The launch stack targets current jax (`jax.shard_map` with `axis_names`,
`jax.make_mesh` with `axis_types`); the pinned container image may carry an
older release where shard_map still lives in jax.experimental with the
(auto, check_rep) spelling and meshes take no axis types. These wrappers
translate between the two so the same launcher code runs on both.
"""

from __future__ import annotations

import jax

__all__ = ["make_mesh_auto", "shard_map"]


def make_mesh_auto(shape: tuple[int, ...],
                   axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """jax.make_mesh with all axes in Auto mode where supported."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        # older jax: meshes are implicitly auto
        return jax.make_mesh(shape, axes)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """`jax.shard_map` on current jax; experimental shard_map otherwise.

    `axis_names` (new spelling) lists the MANUAL axes; the old API instead
    takes `auto` = the complementary set, and calls `check_vma` `check_rep`.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)
