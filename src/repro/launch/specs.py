"""Abstract input specs (ShapeDtypeStruct + PartitionSpec) for every
(architecture x input-shape) cell -- the dry-run's stand-ins. No device
memory is ever allocated here.

For consensus (multi-pod) training, model/optimizer state carries a leading
`pod` replica dimension: each pod is one DDA node with its own parameters;
the batch is split across pods (disjoint data shards, paper section II).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeCell
from repro.models import transformer
from repro.models.common import ModelConfig
from repro.optim import Optimizer
from repro.runtime import sharding as shrules

PyTree = Any


def to_shardings(specs: PyTree, mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def pod_stack(tree: PyTree, specs: PyTree, n_pods: int
              ) -> tuple[PyTree, PyTree]:
    """Prepend a pod-replica dimension (sharded over 'pod') to every leaf."""
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_pods,) + s.shape, s.dtype), tree)
    sspecs = jax.tree.map(lambda s: P("pod", *s), specs,
                          is_leaf=lambda x: isinstance(x, P))
    return stacked, sspecs


def params_and_axes(cfg: ModelConfig) -> tuple[PyTree, PyTree]:
    """Abstract params (ShapeDtypeStructs, no allocation) + logical axes.
    The axes tree is static python data, captured via a side channel since
    eval_shape outputs must be arrays."""
    box = []

    def build(k):
        params, axes = transformer.init(k, cfg)
        box.append(axes)
        return params

    abstract = jax.eval_shape(build, jax.random.PRNGKey(0))
    return abstract, box[0]


def param_specs(cfg: ModelConfig, mesh) -> tuple[PyTree, PyTree]:
    """(abstract params, partition specs) -- no pod dimension."""
    params, axes = params_and_axes(cfg)
    specs = shrules.tree_specs(params, axes, mesh)
    return params, specs


def opt_state_specs(optimizer: Optimizer, abstract_params: PyTree,
                    param_specs_tree: PyTree) -> tuple[PyTree, PyTree]:
    """Abstract optimizer state + specs: moment tensors inherit the param
    specs; scalar counters are replicated."""
    state = jax.eval_shape(optimizer.init, abstract_params)

    def specs_like(subtree):
        leaves_p = jax.tree.leaves(abstract_params)
        leaves_s = jax.tree.leaves(param_specs_tree,
                                   is_leaf=lambda x: isinstance(x, P))
        if len(jax.tree.leaves(subtree)) == len(leaves_p):
            return jax.tree.unflatten(jax.tree.structure(subtree), leaves_s)
        return jax.tree.map(lambda l: P(), subtree)

    if state.inner is None:
        inner_specs = None
    elif isinstance(state.inner, dict):
        inner_specs = {k: specs_like(v) for k, v in state.inner.items()}
    else:
        inner_specs = specs_like(state.inner)
    return state, type(state)(step=P(), inner=inner_specs)


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh,
                *, consensus: bool) -> tuple[PyTree, PyTree]:
    """Training/prefill batch: tokens+labels (+enc for VLM)."""
    has_pod = "pod" in mesh.axis_names
    B, S = cell.global_batch, cell.seq_len
    if has_pod and consensus:
        n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]
        lead, batch_spec = (n_pods,), P("pod", "data", None)
        B = B // n_pods
    elif has_pod:
        lead, batch_spec = (), P(("pod", "data"), None)
    else:
        lead, batch_spec = (), P("data", None)
    tok = jax.ShapeDtypeStruct(lead + (B, S), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    spec = {"tokens": batch_spec, "labels": batch_spec}
    if cfg.family == "vlm":
        enc_spec = P(*batch_spec[:len(lead) + 1], None, None)
        batch["enc"] = jax.ShapeDtypeStruct(
            lead + (B, cfg.num_encoder_tokens, cfg.encoder_dim), cfg.dtype)
        spec["enc"] = enc_spec
    return batch, spec


def cache_specs(cfg: ModelConfig, cell: ShapeCell, mesh
                ) -> tuple[PyTree, PyTree]:
    """Decode cache: abstract tree + specs. Batch is sharded over
    ('pod','data') jointly when a pod axis exists (serving replicates params
    across pods; pods are extra data parallelism)."""
    B, S = cell.global_batch, cell.seq_len
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, S, jnp.bfloat16))
    axes = transformer.cache_axes(cfg)
    rules = dict(shrules.DEFAULT_RULES)
    if "pod" in mesh.axis_names:
        rules["batch"] = (("pod", "data"),)  # composite axis
    specs = _cache_tree_specs(cache, axes, mesh, rules)
    return cache, specs


def _cache_tree_specs(cache, axes, mesh, rules):
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def size_of(cand):
        if isinstance(cand, tuple):  # composite ('pod','data')
            n = 1
            for c in cand:
                n *= mesh_shape.get(c, 1)
            return n
        return mesh_shape.get(cand, 1)

    def one_spec(shape, ax):
        used = set()
        ax = list(ax)
        shape = list(shape)
        out = [None] * len(ax)
        order = sorted(range(len(ax)),
                       key=lambda i: (shrules._ASSIGN_PRIORITY.get(ax[i], 1),
                                      i))
        for i in order:
            name = ax[i]
            for cand in (rules.get(name, ()) if name else ()):
                key = cand if isinstance(cand, str) else tuple(cand)
                if key in used:
                    continue
                if size_of(cand) > 1 and shape[i] % size_of(cand) == 0:
                    out[i] = cand
                    used.add(key)
                    break
        return P(*out)

    flat_v, treedef = jax.tree.flatten(cache)
    flat_a = jax.tree.flatten(axes, is_leaf=shrules.is_axes_leaf)[0]
    specs = [one_spec(v.shape, a) for v, a in zip(flat_v, flat_a)]
    return jax.tree.unflatten(treedef, specs)


def decode_token_specs(cell: ShapeCell, mesh) -> tuple[PyTree, PyTree]:
    B = cell.global_batch
    spec = (P(("pod", "data")) if "pod" in mesh.axis_names else P("data"))
    if B % _spec_size(spec, mesh) != 0:
        spec = P()  # tiny batches (long_500k B=1): replicate
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return ({"tokens": tok, "pos": pos}, {"tokens": spec, "pos": P()})


def _spec_size(spec: P, mesh) -> int:
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for part in spec:
        if part is None:
            continue
        for ax in (part if isinstance(part, tuple) else (part,)):
            n *= mesh_shape.get(ax, 1)
    return n
