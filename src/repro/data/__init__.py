from repro.data.pipeline import (TokenStream, metric_learning_pairs,
                                 nonsmooth_quadratic_problem, partition_rows,
                                 synthetic_mnist_like)
