"""Data pipeline: deterministic synthetic sources, sharded per consensus
node exactly as the paper partitions data (eq. 2: node i owns rows
(i-1)m/n+1 .. im/n), plus a token stream for LM training with per-node
disjoint shards and async host prefetch.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Paper problems
# ---------------------------------------------------------------------------


def synthetic_mnist_like(m: int, d: int = 784, num_classes: int = 10,
                         seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """MNIST-like class-clustered vectors (the paper uses real MNIST; the
    container has no dataset downloads, so we build class clusters with
    matching dimensionality and scale -- documented in DESIGN.md)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 1.0, (num_classes, d))
    labels = rng.integers(0, num_classes, m)
    x = centers[labels] + rng.normal(0.0, 0.8, (m, d))
    return x.astype(np.float32), labels.astype(np.int32)


def metric_learning_pairs(m_pairs: int, d: int = 784, seed: int = 0,
                          num_classes: int = 10):
    """Pairs (u_j, v_j, s_j) for the paper's section V.A metric-learning
    task: s=+1 if same class else -1."""
    x, y = synthetic_mnist_like(2 * m_pairs, d, num_classes, seed)
    u, v = x[0::2], x[1::2]
    s = np.where(y[0::2] == y[1::2], 1.0, -1.0).astype(np.float32)
    return u, v, s


def nonsmooth_quadratic_problem(n_nodes: int, M: int, d: int, seed: int = 0,
                                center_scale: float = 1.0):
    """Paper section V.B: f_i(x) = sum_j max(l^1_j(x), l^2_j(x)) with
    l^xi = ||x - c^xi||^2; node centers drawn far apart so communication is
    essential. Returns centers (n, M, 2, d)."""
    rng = np.random.default_rng(seed)
    node_shift = rng.normal(0.0, center_scale, (n_nodes, 1, 1, d))
    centers = rng.normal(0.0, 0.3, (n_nodes, M, 2, d)) + node_shift
    return centers.astype(np.float32)


def partition_rows(m: int, n_nodes: int) -> list[slice]:
    """Even partition (paper assumes n | m; we give the remainder to the
    last node)."""
    base = m // n_nodes
    out = []
    for i in range(n_nodes):
        lo = i * base
        hi = (i + 1) * base if i < n_nodes - 1 else m
        out.append(slice(lo, hi))
    return out


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TokenStream:
    """Deterministic synthetic LM token stream with disjoint per-node shards
    and background host prefetch.

    Documents are Zipf-sampled token blocks with an injected bigram
    structure so the loss has real signal (a pure-uniform stream trains to
    log(V) and nothing else). Batches are (batch, seq+1); the step splits
    tokens[:, :-1] / labels[:, 1:].
    """

    vocab_size: int
    seq_len: int
    batch_size: int
    node_index: int = 0
    num_nodes: int = 1
    seed: int = 0
    prefetch: int = 2

    def __post_init__(self):
        self._step = 0
        self._q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + self.node_index) * 977 + step)
        B, S, V = self.batch_size, self.seq_len + 1, self.vocab_size
        base = rng.zipf(1.3, size=(B, S)).astype(np.int64)
        toks = (base - 1) % V
        # bigram structure: every even position strongly predicts the next
        toks[:, 1::2] = (toks[:, 0::2][:, : toks[:, 1::2].shape[1]]
                         * 31 + 7) % V
        return toks.astype(np.int32)

    def _producer(self):
        step = 0
        while not self._stop.is_set():
            try:
                self._q.put(self._batch_at(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        toks = self._q.get()
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    def close(self):
        self._stop.set()
