"""repro: reproduction of "Communication/Computation Tradeoffs in
Consensus-Based Distributed Optimization", grown into a multi-backend
JAX system.

The package root re-exports the experiment API lazily (PEP 562), so
`import repro; repro.run(spec)` works without paying the full experiment
stack on `import repro.core`-style imports.
"""

_EXPERIMENT_API = (
    "ComponentSpec",
    "ExperimentSpec",
    "RunResult",
    "run",
    "run_all",
    "run_sweep",
)

__all__ = list(_EXPERIMENT_API)


def __getattr__(name):
    if name in _EXPERIMENT_API:
        from repro import experiments
        return getattr(experiments, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
