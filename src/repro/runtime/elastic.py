"""Elastic scaling: add/remove consensus nodes mid-run.

Consensus data parallelism makes elasticity cheap compared to synchronous
all-reduce DP: membership changes only rebuild the (host-side) graph and
re-partition the data; there is no global bitwise-identical state to
re-materialize. New nodes warm-start from the average of the survivors
(the consensus estimate), which is exactly what DDA drives all nodes toward
anyway.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.core.graphs import CommGraph, build_graph
from repro.data.pipeline import partition_rows

PyTree = Any


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_n: int
    new_n: int
    graph: CommGraph
    data_slices: list
    survivor_ids: tuple[int, ...]


def plan_rescale(topology: str, old_n: int, new_n: int, m_rows: int,
                 *, failed: Sequence[int] = (), k: int = 4,
                 seed: int = 0) -> RescalePlan:
    failed_set = set(failed)
    bad = sorted(i for i in failed_set if not 0 <= i < old_n)
    if bad:
        raise ValueError(
            f"failed ids {bad} out of range for old_n={old_n}")
    survivors = tuple(i for i in range(old_n) if i not in failed_set)
    if not survivors:
        raise ValueError(
            f"all {old_n} nodes failed: no survivors to rescale from")
    graph = build_graph(topology, new_n, k=k, seed=seed)
    return RescalePlan(old_n=old_n, new_n=new_n, graph=graph,
                       data_slices=partition_rows(m_rows, new_n),
                       survivor_ids=survivors)


def rescale_state(stacked_state: PyTree, plan: RescalePlan) -> PyTree:
    """Map an (old_n, ...) stacked node state to (new_n, ...).

    Surviving rows carry over (up to new_n of them); new rows initialize to
    the survivors' average -- the consensus warm start."""
    surv = np.asarray(plan.survivor_ids)

    def one(a):
        a = np.asarray(a)
        alive = a[surv]
        mean = alive.mean(axis=0, keepdims=True)
        rows = [alive[i % len(alive)] if i < len(alive) else mean[0]
                for i in range(plan.new_n)]
        return jax.numpy.asarray(np.stack(rows))

    return jax.tree.map(one, stacked_state)
