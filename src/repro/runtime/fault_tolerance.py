"""Fault tolerance: straggler-tolerant consensus and failure handling.

The paper's motivation (section I): consensus algorithms are "immune to slow
nodes that use part of their computation and communication resources for
unrelated tasks" and tolerate delays (ref [9]). This module makes those
claims operational:

  * deadline gossip  -- a round's mixing proceeds with whatever messages
    arrived by the deadline; missing neighbors' weights fold back into the
    self weight (row-stochasticity preserved, so iterates stay in the convex
    hull; the doubly-stochastic property is restored on the next full round)
  * stale mixing     -- late messages are still used one round later
    (delay-tolerant DDA), implemented in core.consensus.mix_stale
  * crash + restart  -- checkpoint/resume via repro.checkpoint; on a node
    loss the elastic module (runtime.elastic) rebuilds the graph

`StragglerModel` simulates per-node slowdown for tests/benchmarks: each
round each node is slow with probability p_slow (multiplier m_slow), and a
message misses the deadline when sender_delay > deadline.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graphs import CommGraph


@dataclasses.dataclass
class StragglerModel:
    p_slow: float = 0.1
    m_slow: float = 4.0          # slowdown multiplier for a straggling node
    deadline: float = 2.0        # in units of the median round time
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def sample_round(self, n: int) -> np.ndarray:
        """Per-node completion time for one round (median-normalized)."""
        slow = self._rng.random(n) < self.p_slow
        return np.where(slow, self.m_slow, 1.0)

    def arrival_mask(self, n: int) -> np.ndarray:
        """mask[j] = True if node j's message makes the deadline."""
        return self.sample_round(n) <= self.deadline


def degraded_matrix(graph: CommGraph, arrived: np.ndarray) -> np.ndarray:
    """Mixing matrix for a round where only `arrived[j]` messages landed.

    Every weight p_ij for a missing j (j != i) is folded into p_ii: rows
    stay stochastic and the update remains a convex combination. The result
    is generally NOT doubly stochastic -- consensus-weighted averaging with
    occasional drop rounds still converges when drops are independent and
    the expected graph is connected (tested empirically in
    tests/test_fault_tolerance.py)."""
    P = graph.mixing_matrix().copy()
    n = P.shape[0]
    for j in range(n):
        if not arrived[j]:
            col = P[:, j].copy()
            for i in range(n):
                if i != j:
                    P[i, i] += col[i]
                    P[i, j] = 0.0
    return P


def effective_round_time(times: np.ndarray, deadline: float,
                         comm_cost: float) -> float:
    """Wall time of a deadline-gossip round: stragglers beyond the deadline
    do NOT gate the round (that is the point); the round costs the deadline
    plus the communication term."""
    return float(min(times.max(), deadline) + comm_cost)


def arrival_reweighted_matrix(P: np.ndarray,
                              arrive_prob: np.ndarray) -> np.ndarray:
    """EXPECTED mixing matrix when sender j's message lands in time with
    probability `arrive_prob[j]` (independently per round).

    The per-round realization is `degraded_matrix` over a Bernoulli arrival
    mask; averaging over the mask gives, in closed form,

        P'_ij = p_ij * a_j                    (j != i)
        P'_ii = p_ii + sum_{j != i} p_ij (1 - a_j)

    -- each straggler's weight shrinks toward the receiver's self weight in
    proportion to how often it misses. Rows stay exactly stochastic;
    columns generally do not (a slow sender is under-heard), which is why
    the closed-loop controller (`repro.adaptive.StragglerReweighter`)
    re-balances the result with `sinkhorn_project` before trusting its
    lambda2 for h_opt.
    """
    P = np.asarray(P, dtype=np.float64)
    a = np.asarray(arrive_prob, dtype=np.float64)
    if not np.all((a >= 0.0) & (a <= 1.0)):  # also rejects NaN
        raise ValueError("arrival probabilities must lie in [0, 1] "
                         "(and contain no NaN)")
    Pr = P * a[None, :]
    lost = P @ (1.0 - a) - np.diag(P) * (1.0 - a)   # mass from late senders
    np.fill_diagonal(Pr, np.diag(P) + lost)
    return Pr


def sinkhorn_project(P: np.ndarray, iters: int = 20000,
                     tol: float = 1e-9, accept_tol: float = 1e-6
                     ) -> np.ndarray:
    """Nearest-in-KL doubly-stochastic rescaling D1 @ P @ D2 (Sinkhorn-Knopp).

    Requires a nonnegative P with total support; every mixing matrix here
    has a strictly positive diagonal, which is sufficient. Iterates to
    `tol`; the budget covers the slowest realistic case (a 64-ring with
    floor-clamped stragglers balances in ~11k iterations; well-connected
    graphs take a few hundred). If the budget runs out but the residual is
    already below `accept_tol` -- imbalance far below anything a lambda2
    estimate can feel -- the near-balanced matrix is returned; a residual
    above that means the input genuinely lacks support (or the caller's
    model broke), and raising beats silently poisoning the spectral-gap
    estimate downstream.
    """
    P = np.asarray(P, dtype=np.float64).copy()
    if np.any(P < 0.0):
        raise ValueError("sinkhorn_project needs a nonnegative matrix")
    for _ in range(iters):
        P /= P.sum(axis=1, keepdims=True)
        P /= P.sum(axis=0, keepdims=True)
        if (np.abs(P.sum(axis=1) - 1.0).max() < tol
                and np.abs(P.sum(axis=0) - 1.0).max() < tol):
            return _resymmetrize(P)
    resid = max(np.abs(P.sum(axis=1) - 1.0).max(),
                np.abs(P.sum(axis=0) - 1.0).max())
    if resid < accept_tol:
        return _resymmetrize(P)
    raise ValueError(
        f"Sinkhorn failed to reach doubly-stochastic within {iters} iters "
        f"(residual {resid:.2e} > accept_tol {accept_tol:.0e})")


def _resymmetrize(P: np.ndarray) -> np.ndarray:
    """The Sinkhorn limit of the arrival-reweighted matrices built here (a
    symmetric base times per-sender arrival scalings) is symmetric, but
    the finite iterate carries ~tol asymmetry because it stops right
    after a row pass. When the residual asymmetry is at iteration-residue
    scale, averaging with the transpose snaps it to EXACT symmetry at no
    cost to the row/column sums (the perturbation is bounded by the same
    residue) -- and lets downstream lambda2() take its exact-symmetry
    `eigvalsh` fast path instead of paying general `eigvals` on every
    controller retune. A genuinely asymmetric result is left alone."""
    if np.allclose(P, P.T, rtol=0.0, atol=1e-8):
        return (P + P.T) / 2.0
    return P
