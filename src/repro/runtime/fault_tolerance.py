"""Fault tolerance: straggler-tolerant consensus and failure handling.

The paper's motivation (section I): consensus algorithms are "immune to slow
nodes that use part of their computation and communication resources for
unrelated tasks" and tolerate delays (ref [9]). This module makes those
claims operational:

  * deadline gossip  -- a round's mixing proceeds with whatever messages
    arrived by the deadline; missing neighbors' weights fold back into the
    self weight (row-stochasticity preserved, so iterates stay in the convex
    hull; the doubly-stochastic property is restored on the next full round)
  * stale mixing     -- late messages are still used one round later
    (delay-tolerant DDA), implemented in core.consensus.mix_stale
  * crash + restart  -- checkpoint/resume via repro.checkpoint; on a node
    loss the elastic module (runtime.elastic) rebuilds the graph

`StragglerModel` simulates per-node slowdown for tests/benchmarks: each
round each node is slow with probability p_slow (multiplier m_slow), and a
message misses the deadline when sender_delay > deadline.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graphs import CommGraph


@dataclasses.dataclass
class StragglerModel:
    p_slow: float = 0.1
    m_slow: float = 4.0          # slowdown multiplier for a straggling node
    deadline: float = 2.0        # in units of the median round time
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def sample_round(self, n: int) -> np.ndarray:
        """Per-node completion time for one round (median-normalized)."""
        slow = self._rng.random(n) < self.p_slow
        return np.where(slow, self.m_slow, 1.0)

    def arrival_mask(self, n: int) -> np.ndarray:
        """mask[j] = True if node j's message makes the deadline."""
        return self.sample_round(n) <= self.deadline


def degraded_matrix(graph: CommGraph, arrived: np.ndarray) -> np.ndarray:
    """Mixing matrix for a round where only `arrived[j]` messages landed.

    Every weight p_ij for a missing j (j != i) is folded into p_ii: rows
    stay stochastic and the update remains a convex combination. The result
    is generally NOT doubly stochastic -- consensus-weighted averaging with
    occasional drop rounds still converges when drops are independent and
    the expected graph is connected (tested empirically in
    tests/test_fault_tolerance.py)."""
    P = graph.mixing_matrix().copy()
    n = P.shape[0]
    for j in range(n):
        if not arrived[j]:
            col = P[:, j].copy()
            for i in range(n):
                if i != j:
                    P[i, i] += col[i]
                    P[i, j] = 0.0
    return P


def effective_round_time(times: np.ndarray, deadline: float,
                         comm_cost: float) -> float:
    """Wall time of a deadline-gossip round: stragglers beyond the deadline
    do NOT gate the round (that is the point); the round costs the deadline
    plus the communication term."""
    return float(min(times.max(), deadline) + comm_cost)
