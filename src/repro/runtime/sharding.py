"""Logical-axis sharding rules (MaxText-style) and activation constraints.

Model code annotates params and activations with LOGICAL axis names
("batch", "embed", "q_heads", ...). A `Rules` table maps logical names to
mesh axes; `constrain(x, axes)` applies `with_sharding_constraint` when a
rules context is active (set by the launcher), and is a no-op otherwise so
model code runs unmodified on a single CPU device in tests.

A logical axis is only sharded if the dimension is divisible by the mesh
axis size (e.g. llama3's 8 KV heads stay replicated on a model=16 mesh and
the KV cache is sharded over sequence instead -- see DEFAULT_RULES).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Sequence

import jax
from jax.sharding import PartitionSpec as P

PyTree = Any

# logical axis -> preference-ordered candidate mesh axes
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("data",),
    "seq": (),
    # residual stream BETWEEN blocks: sequence-parallel over 'model'
    # (Megatron SP). Cuts the per-layer saved-activation footprint by the
    # model-axis size; XLA re-gathers at attention entry.
    "seq_sp": ("model",),
    "cache_seq": ("model",),       # decode KV/state cache: sequence-sharded
    "embed": ("data",),            # FSDP: shard params' d_model over data
    "embed_act": (),               # activations' d_model: replicated (TP collects)
    "q_heads": ("model",),
    "kv_heads": ("model",),
    # head dim is only ever sharded as the decode-cache fallback (weights'
    # head dims lose to q/kv_heads via _ASSIGN_PRIORITY + the used-set)
    "head": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_mlp": (),
    "kv_lora": (),
    "q_lora": (),   # never steal 'model' from q_heads in the MLA up-projs
    "conv": (),
    "state": (),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "layers": (),
    "lora": (),
    "enc_tokens": ("model",),
    "enc_embed": (),
}


class _Ctx(threading.local):
    def __init__(self):
        self.rules: dict[str, tuple[str, ...]] | None = None
        self.mesh: jax.sharding.Mesh | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_rules(rules: dict[str, tuple[str, ...]], mesh: jax.sharding.Mesh):
    prev = (_CTX.rules, _CTX.mesh)
    _CTX.rules, _CTX.mesh = rules, mesh
    try:
        yield
    finally:
        _CTX.rules, _CTX.mesh = prev


# Lower value = assigned first when several logical axes compete for the
# same mesh axis. cache_seq is the LAST resort: a dynamic-update-slice into
# a sharded dim forces XLA to reshard the whole cache every decode step, so
# decode caches prefer head-sharding (kv_heads, then head) over seq.
_ASSIGN_PRIORITY = {
    "batch": 0, "seq_sp": 0, "embed": 0, "experts": 0, "enc_tokens": 0,
    "kv_heads": 1, "q_heads": 1, "mlp": 1, "vocab": 1, "ssm_inner": 1,
    "ssm_heads": 1,
    "head": 2,
    "cache_seq": 3,
}


def logical_to_spec(shape: Sequence[int], axes: Sequence[str | None],
                    rules: dict[str, tuple[str, ...]],
                    mesh_shape: dict[str, int]) -> P:
    """Resolve logical axes to a PartitionSpec, honoring divisibility and
    never assigning one mesh axis twice. Competing axes are resolved in
    _ASSIGN_PRIORITY order (then position order)."""
    used: set[str] = set()
    out: list[Any] = [None] * len(list(axes))
    order = sorted(range(len(out)),
                   key=lambda i: (_ASSIGN_PRIORITY.get(list(axes)[i], 1), i))
    axes = list(axes)
    shape = list(shape)
    for i in order:
        name = axes[i]
        for cand in (rules.get(name, ()) if name else ()):
            if cand in used:
                continue
            size = mesh_shape.get(cand, 1)
            if size > 1 and shape[i] % size == 0:
                out[i] = cand
                used.add(cand)
                break
    return P(*out)


def spec_for(x, axes: Sequence[str | None],
             rules: dict[str, tuple[str, ...]] | None = None,
             mesh: jax.sharding.Mesh | None = None) -> P:
    rules = rules if rules is not None else _CTX.rules
    mesh = mesh if mesh is not None else _CTX.mesh
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return logical_to_spec(x.shape, axes, rules, mesh_shape)


def rules_active() -> bool:
    """True when the launcher installed sharding rules (production mesh);
    model code uses this to pick distribution-aware compute paths."""
    return _CTX.rules is not None and _CTX.mesh is not None


def constrain(x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
    """Apply a sharding constraint when a rules context is active."""
    if _CTX.rules is None or _CTX.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_CTX.mesh, spec_for(x, axes)))


def is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def tree_specs(abstract_tree: PyTree, axes_tree: PyTree,
               mesh: jax.sharding.Mesh,
               rules: dict[str, tuple[str, ...]] | None = None) -> PyTree:
    """PartitionSpecs for a whole tree: flatten the value tree and the
    parallel logical-axes tree (whose leaves are tuples) independently."""
    rules = rules if rules is not None else DEFAULT_RULES
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat_v, treedef = jax.tree.flatten(abstract_tree)
    flat_a = jax.tree.flatten(axes_tree, is_leaf=is_axes_leaf)[0]
    assert len(flat_v) == len(flat_a), (len(flat_v), len(flat_a))
    specs = [logical_to_spec(v.shape, a, rules, mesh_shape)
             for v, a in zip(flat_v, flat_a)]
    return jax.tree.unflatten(treedef, specs)


def tree_shardings(abstract_tree: PyTree, axes_tree: PyTree,
                   mesh: jax.sharding.Mesh,
                   rules: dict[str, tuple[str, ...]] | None = None) -> PyTree:
    """NamedShardings for a whole tree (in_shardings / checkpoint layout)."""
    specs = tree_specs(abstract_tree, axes_tree, mesh, rules)
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
