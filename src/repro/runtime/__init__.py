from repro.runtime.sharding import (DEFAULT_RULES, constrain, tree_shardings,
                                    tree_specs, use_rules)
