"""Span/counter tracer all three execution backends emit into.

Two clocks, never mixed in one event:

  * ``"host"`` -- wall-clock seconds from `time.perf_counter()`, relative
    to the tracer's construction time. Used for the coarse phase spans
    (build / compile / execute / eval) every backend emits.
  * ``"sim"`` -- the backend's own simulated-time axis (the netsim event
    clock, or the dense simulator's closed-form `iters*(1/n + k r)`
    charge), in sim units. Used for per-event detail spans (node steps,
    message flights, retunes).

The contract that keeps tracing out of the engines' bit-identity budget:
detail (per-event) emission only happens when `detail=True`, and the
engines hold a pre-resolved local ``tr = tracer if tracer is not None and
tracer.detail else None`` so the hot path carries exactly one
``if tr is not None`` branch -- the same pattern as the controller hooks.
A phase-level tracer (the default for every `repro.run()`) never threads
into the event loops at all.

Events are capped at `max_events`; past the cap the tracer counts drops
instead of growing without bound (`events_dropped`). Counters and series
are never dropped.
"""

from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager
from typing import Any, Iterator, Sequence

__all__ = ["TraceEvent", "Tracer"]


@dataclasses.dataclass
class TraceEvent:
    """One trace event: a completed span (`dur > 0` or explicit) or an
    instant (`dur == 0.0` and `instant=True`)."""

    name: str
    t0: float                 # start time (host: s since tracer start; sim: sim units)
    dur: float                # duration in the event's clock units
    clock: str = "host"       # "host" | "sim"
    track: str = "main"       # display lane (Perfetto thread)
    instant: bool = False
    args: dict[str, Any] = dataclasses.field(default_factory=dict)


class Tracer:
    """Collects spans, instants, counters and time series for one run.

    Args:
      detail: when True, backends additionally emit per-event sim-time
        spans (node steps, message flights, retunes). When False (the
        default), only phase-level spans and counters are recorded and the
        engines' event loops are never entered with a tracer at all.
      max_events: hard cap on stored events; further events increment
        `events_dropped` instead of being stored.
    """

    def __init__(self, detail: bool = False, max_events: int = 200_000):
        self.detail = bool(detail)
        self.max_events = int(max_events)
        self.events: list[TraceEvent] = []
        self.counters: dict[str, float] = {}
        self.series: dict[str, list[tuple[float, float]]] = {}
        self.events_dropped = 0
        self._t_origin = time.perf_counter()

    # -- host-clock phases ---------------------------------------------------

    def now(self) -> float:
        """Host seconds since this tracer was created."""
        return time.perf_counter() - self._t_origin

    @contextmanager
    def span(self, name: str, track: str = "main", **args: Any) -> Iterator[None]:
        """Host-clock phase span around a `with` block."""
        t0 = self.now()
        try:
            yield
        finally:
            self._emit(TraceEvent(name=name, t0=t0, dur=self.now() - t0,
                                  clock="host", track=track, args=args))

    def add_host_span(self, name: str, t0: float, dur: float,
                      track: str = "main", **args: Any) -> None:
        """Record an already-measured host-clock span (seconds, relative to
        the tracer's origin -- use `now()` to take timestamps)."""
        self._emit(TraceEvent(name=name, t0=float(t0), dur=float(dur),
                              clock="host", track=track, args=args))

    # -- sim-clock detail ----------------------------------------------------

    def add_span(self, name: str, t0: float, dur: float,
                 track: str = "sim", **args: Any) -> None:
        """Record one sim-time span (e.g. a node step or message flight)."""
        self._emit(TraceEvent(name=name, t0=float(t0), dur=float(dur),
                              clock="sim", track=track, args=args))

    def add_spans(self, name: str, t0s: Sequence[float], durs: Sequence[float],
                  tracks: Sequence[str] | None = None,
                  track: str = "sim") -> None:
        """Batch form of `add_span` for the vectorized engine's chunked
        event groups (one call per chunk, not per node)."""
        if tracks is None:
            for t0, dur in zip(t0s, durs):
                self._emit(TraceEvent(name=name, t0=float(t0), dur=float(dur),
                                      clock="sim", track=track))
        else:
            for t0, dur, trk in zip(t0s, durs, tracks):
                self._emit(TraceEvent(name=name, t0=float(t0), dur=float(dur),
                                      clock="sim", track=str(trk)))

    def add_instant(self, name: str, t: float, clock: str = "sim",
                    track: str = "sim", **args: Any) -> None:
        """Record a zero-duration marker (retune, rewire, eval point)."""
        self._emit(TraceEvent(name=name, t0=float(t), dur=0.0, clock=clock,
                              track=track, instant=True, args=args))

    # -- counters / series ---------------------------------------------------

    def count(self, name: str, n: float = 1.0) -> None:
        """Increment a named counter (messages-sent, bytes-on-wire, ...)."""
        self.counters[name] = self.counters.get(name, 0.0) + n

    def record_series(self, name: str, t: float, value: float) -> None:
        """Append one (t, value) sample to a named time series (e.g. the
        observed r-hat trajectory on the sim clock)."""
        self.series.setdefault(name, []).append((float(t), float(value)))

    # -- aggregation ---------------------------------------------------------

    def phase_totals(self) -> dict[str, dict[str, float]]:
        """Aggregate host-clock spans by name: total seconds and count."""
        out: dict[str, dict[str, float]] = {}
        for ev in self.events:
            if ev.clock != "host" or ev.instant:
                continue
            agg = out.setdefault(ev.name, {"total_s": 0.0, "count": 0})
            agg["total_s"] += ev.dur
            agg["count"] += 1
        return out

    # -- internals -----------------------------------------------------------

    def _emit(self, ev: TraceEvent) -> None:
        if len(self.events) >= self.max_events:
            self.events_dropped += 1
            return
        self.events.append(ev)
