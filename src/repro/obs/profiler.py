"""Opt-in `jax.profiler` hook for the dense scan program.

`profile_ctx(profile_dir)` wraps `jax.profiler.start_trace/stop_trace`
around a block; with `profile_dir=None` it is a no-op context (the
default for every run). The dense runner enters it around the scanned
program's dispatch when `ExperimentSpec.profile_dir` is set, producing a
TensorBoard-loadable XLA profile alongside repro's own Chrome trace.
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Iterator

__all__ = ["profile_ctx"]


@contextmanager
def profile_ctx(profile_dir: str | None) -> Iterator[None]:
    if profile_dir is None:
        yield
        return
    import jax

    jax.profiler.start_trace(str(profile_dir))
    try:
        yield
    finally:
        jax.profiler.stop_trace()
