"""`repro.obs` -- backend-agnostic observability: tracing, metrics, export.

The paper's central quantity r is a *measured* ratio of communication time
to computation time, so the stack that measures it needs a place to put its
measurements. This package is that place, three layers deep:

  * `Tracer` (obs.tracer) -- span/counter/series collector all three
    execution backends emit into. Phase spans (build/compile/execute/eval)
    ride the host clock; per-event detail spans (node steps, message
    flights, retunes) ride the backend's own sim clock and are emitted by
    the netsim engines only when `detail` tracing is requested -- the same
    "no hot-path branches unless attached" pattern the AdaptiveController
    hooks use, so tracing cannot perturb the engines' bit-identity
    guarantees.

  * `RunMetrics` (obs.metrics) -- the frozen, JSON-exact metrics block
    every `repro.run()` attaches to its `RunResult`: compile/execute wall
    split, message/byte/drop counters, retune history, per-node step-time
    quantiles and the observed r-hat trajectory. Serialized through the
    same strict-RFC path as the rest of the result (`json_sanitize`).

  * export + tooling (obs.export, obs.summary) -- Chrome-trace/Perfetto
    JSON and JSONL writers for the tracer's event stream, the shared
    strict-JSON artifact writer (one code path for CI smoke artifacts and
    the convergence tier's failure dumps), and the text renderer behind
    `python -m repro.experiments trace <result.json>`.

`obs.profiler.profile_ctx` is the opt-in `jax.profiler` hook
(`ExperimentSpec.profile_dir`) the dense backend wraps around its scanned
program.
"""

from repro.obs.export import (chrome_trace_events, write_chrome_trace,
                              write_json_artifact, write_jsonl)
from repro.obs.metrics import (METRICS_VERSION, RunMetrics,
                               sample_quantiles)
from repro.obs.profiler import profile_ctx
from repro.obs.summary import render_summary
from repro.obs.tracer import TraceEvent, Tracer

__all__ = [
    "METRICS_VERSION",
    "RunMetrics",
    "TraceEvent",
    "Tracer",
    "chrome_trace_events",
    "profile_ctx",
    "render_summary",
    "sample_quantiles",
    "write_chrome_trace",
    "write_json_artifact",
    "write_jsonl",
]
