"""Text rendering for `python -m repro.experiments trace <result.json>`.

`render_summary` takes a RunResult *dict* (the parsed JSON file, not the
reconstructed dataclass) so it can render any result artifact -- including
pre-metrics files, for which it says so instead of failing.
"""

from __future__ import annotations

__all__ = ["render_summary"]


def _fmt_s(v) -> str:
    return "-" if v is None else f"{v:.4f} s"


def _fmt_num(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float) and not v.is_integer():
        return f"{v:.4g}"
    return f"{int(v):,}"


def _rows(pairs, indent="  ") -> list[str]:
    """Two-column aligned rows from (label, value) pairs."""
    pairs = [(str(k), str(v)) for k, v in pairs]
    if not pairs:
        return []
    width = max(len(k) for k, _ in pairs)
    return [f"{indent}{k:<{width}}  {v}" for k, v in pairs]


def render_summary(result: dict) -> str:
    """Render a phase-breakdown / counter / r-hat summary of one RunResult
    JSON dict (as written by `repro.experiments run --out` or
    `RunResult.to_json`)."""
    spec = result.get("spec", {})
    backend = result.get("backend", {})
    name = spec.get("name", "?")
    kind = backend.get("kind", "?")
    params = backend.get("params") or {}
    tag = kind + (f"/{params['engine']}" if "engine" in params else "")
    wall = result.get("wall_s")

    lines = [f"run {name!r}  backend={tag}  wall={_fmt_s(wall)}"]

    m = result.get("metrics")
    if m is None:
        lines.append("  (no metrics block -- result predates repro.obs)")
        return "\n".join(lines)

    # -- phase breakdown -----------------------------------------------------
    phase_rows = [("compile", m.get("compile_s")),
                  ("execute", m.get("execute_s"))]
    if m.get("eval_s") is not None:
        phase_rows.append(("eval", m.get("eval_s")))
    for pname, agg in sorted((m.get("phases") or {}).items()):
        if pname in ("compile", "execute", "eval"):
            continue
        phase_rows.append((pname, agg.get("total_s")))
    total = sum(v for _, v in phase_rows if v) or None
    lines.append("phases:")
    lines += _rows([
        (pname, _fmt_s(v) + (f"  ({100.0 * v / total:5.1f}%)"
                             if v is not None and total else ""))
        for pname, v in phase_rows])

    # -- counters ------------------------------------------------------------
    counter_rows = [("msgs", m.get("msgs")),
                    ("bytes_on_wire", m.get("bytes_on_wire")),
                    ("gossip_rounds", m.get("gossip_rounds")),
                    ("drops", m.get("drops")),
                    ("retunes", m.get("retunes"))]
    extra = sorted((m.get("counters") or {}).items(),
                   key=lambda kv: -abs(kv[1]))
    counter_rows += [(k, v) for k, v in extra[:8]
                     if k not in dict(counter_rows)]
    lines.append("counters:")
    lines += _rows([(k, _fmt_num(v)) for k, v in counter_rows])

    # -- fault injection -----------------------------------------------------
    faults = m.get("faults")
    if faults:
        lines.append("faults:")
        lines += _rows([(k, _fmt_num(v)) for k, v in sorted(faults.items())])

    # -- compression ---------------------------------------------------------
    comp = m.get("compression")
    if comp:
        lines.append("compression:")
        comp_rows = [("kind", comp.get("kind", "?")),
                     ("wire_ratio", _fmt_num(comp.get("wire_ratio"))),
                     ("bytes_saved", _fmt_num(comp.get("bytes_saved")))]
        rns = comp.get("residual_norms") or []
        if rns:
            comp_rows.append(
                ("ef_residual", f"{rns[0]:.4g} @ start -> "
                                f"{rns[-1]:.4g} @ end ({len(rns)} pts)"))
        lines += _rows(comp_rows)

    # -- step-time quantiles -------------------------------------------------
    q = m.get("step_time_quantiles")
    if q:
        lines.append(f"step times ({q.get('unit', '?')}-clock, "
                     f"n={q.get('n', '?')}):")
        lines += _rows([(p, f"{q[p]:.6g}")
                        for p in ("p50", "p90", "p99", "max") if p in q])

    # -- r-hat vs r ----------------------------------------------------------
    rhat_rows = [("configured r", spec.get("r"))]
    if m.get("r_hat") is not None:
        rhat_rows.append(("r̂ (controller)", m.get("r_hat")))
    meas = result.get("r_measurement") or {}
    if meas.get("r") is not None:
        rhat_rows.append(("r empirical", meas.get("r")))
    pred = result.get("predictions") or {}
    for key in ("h_opt", "n_opt", "tau_eps"):
        if pred.get(key) is not None:
            rhat_rows.append((f"{key} (predicted)", pred.get(key)))
    lines.append("r̂ vs r:")
    lines += _rows([(k, "-" if v is None else f"{v:.6g}"
                     if isinstance(v, float) else str(v))
                    for k, v in rhat_rows])

    # -- retune history ------------------------------------------------------
    hist = m.get("retune_history") or []
    if hist:
        lines.append("retunes:")
        lines += _rows([(f"t={from_t:g}",
                         f"h={int(h)}  (r̂={r_hat:.4g}, "
                         f"raw h_opt={h_opt_raw:.4g})")
                        for from_t, h, h_opt_raw, r_hat, _lam2 in hist])
    traj = m.get("r_hat_trajectory") or []
    if traj:
        t0, v0 = traj[0]
        t1, v1 = traj[-1]
        lines.append(f"r̂ trajectory: {len(traj)} samples, "
                     f"{v0:.4g} @ t={t0:g} -> {v1:.4g} @ t={t1:g}")
    return "\n".join(lines)
