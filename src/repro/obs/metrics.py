"""`RunMetrics` -- the frozen metrics block every `repro.run()` returns.

One schema across all three backends, so downstream tooling (the `trace`
CLI, the bench regression files, the future serving layer) reads one
shape regardless of which engine produced it:

  * `compile_s` / `execute_s` -- the host wall split of the run.  For the
    dense backend these are the jit lower+compile time vs the blocked
    execution time of the scanned program (their sum is `RunResult.wall_s`,
    preserving JSON back-compat).  The netsim engines have no compile
    phase (`compile_s == 0.0`); launch-dryrun reports its AOT compile
    walls.
  * message/byte counters -- `msgs` is messages sent (netsim: actual
    sends including drops; dense/launch: the closed-form n*k per gossip
    round), `bytes_on_wire` assumes the backend's payload width.
  * `retunes` / `retune_history` / `r_hat` / `r_hat_trajectory` -- the
    adaptive controller's observable record: what r-hat it measured when,
    and which h it spliced in where.
  * `step_time_quantiles` -- per-node step-time distribution
    (p50/p90/p99/max); the `unit` key says which clock the samples rode
    ("sim" for netsim, "host" for dense per-iteration walls and launch
    per-step walls).
  * `faults` -- fault-injection record for netsim runs with a FaultPlan
    attached (crashes/restarts/joins/leaves, summed sim-time downtime,
    partition epochs, link flaps, checkpoints taken, sends refused at
    partitioned links, and link-layer retransmits); `None` on fault-free
    runs, `{"retransmits": k}` when only bounded retry was configured.
  * `compression` -- compressed-gossip record for runs with
    `ExperimentSpec.compression` attached: the compressor `kind`, its
    bytes-on-wire `wire_ratio` c, `bytes_saved` vs uncompressed payloads,
    and the `residual_norms` trajectory (mean per-node error-feedback
    residual norm at each trace point); `None` on uncompressed runs.
  * `phases` / `counters` -- the tracer's aggregates, verbatim.
  * `notes` -- free-form string diagnostics (vmap-fallback reasons, the
    serving packer's solo reasons); empty on clean runs.

Serialization is strict-RFC via the same `json_sanitize` path as
`RunResult` (inf/nan -> null, numpy scalars -> Python), and
`from_dict(to_dict(m)) == m` exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

__all__ = ["METRICS_VERSION", "RunMetrics", "sample_quantiles"]

METRICS_VERSION = 1


def _freeze_pairs(pairs: Any) -> tuple:
    """Normalize a list/tuple of 2-sequences into a tuple of float pairs,
    so JSON round-trips (lists of lists) compare equal to the original."""
    return tuple((float(a), float(b)) for a, b in pairs)


def _freeze_retunes(history: Any) -> tuple:
    """Normalize retune records into (from_t, h, h_opt_raw, r_hat, lam2)
    float/int tuples; accepts Retune dataclasses, dicts, or sequences."""
    out = []
    for r in history:
        if dataclasses.is_dataclass(r) and not isinstance(r, type):
            r = dataclasses.asdict(r)
        if isinstance(r, dict):
            rec = (r["from_t"], r["h"], r["h_opt_raw"], r["r_hat"], r["lam2"])
        else:
            rec = tuple(r)
        from_t, h, h_opt_raw, r_hat, lam2 = rec
        out.append((float(from_t), int(h), float(h_opt_raw), float(r_hat),
                    float(lam2)))
    return tuple(out)


def sample_quantiles(samples: Any, unit: str) -> dict[str, float] | None:
    """p50/p90/p99/max/n summary of a timing sample array, or None when
    there are no samples. `unit` says which clock the samples rode
    ("sim" or "host")."""
    arr = np.asarray(list(samples), dtype=np.float64)
    if arr.size == 0:
        return None
    return {
        "p50": float(np.percentile(arr, 50)),
        "p90": float(np.percentile(arr, 90)),
        "p99": float(np.percentile(arr, 99)),
        "max": float(np.max(arr)),
        "n": int(arr.size),
        "unit": str(unit),
    }


@dataclasses.dataclass(frozen=True)
class RunMetrics:
    """Frozen per-run metrics block; see module docstring for field
    semantics. All fields are optional-with-defaults so backends populate
    what they can observe and leave the rest at identity."""

    compile_s: float = 0.0
    execute_s: float = 0.0
    eval_s: float | None = None
    msgs: int = 0
    bytes_on_wire: float = 0.0
    drops: int = 0
    gossip_rounds: int = 0
    retunes: int = 0
    retune_history: tuple = ()
    r_hat: float | None = None
    r_hat_trajectory: tuple = ()
    step_time_quantiles: dict | None = None
    faults: dict | None = None
    compression: dict | None = None
    phases: dict = dataclasses.field(default_factory=dict)
    counters: dict = dataclasses.field(default_factory=dict)
    #: free-form string diagnostics (e.g. "vmap_fallback": why a sweep
    #: degraded to serial, "solo_reason": why the serving packer ran a
    #: spec unbatched). Absent keys mean "nothing to report"; old
    #: serialized blocks load with the empty default.
    notes: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        # normalize sequence fields so JSON round-trips compare equal
        object.__setattr__(self, "retune_history",
                           _freeze_retunes(self.retune_history))
        object.__setattr__(self, "r_hat_trajectory",
                           _freeze_pairs(self.r_hat_trajectory))

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        from repro.core.dda import json_sanitize

        d = dataclasses.asdict(self)
        d["metrics_version"] = METRICS_VERSION
        return json_sanitize(d)

    @classmethod
    def from_dict(cls, d: dict) -> "RunMetrics":
        d = dict(d)
        version = d.pop("metrics_version", None)
        if version != METRICS_VERSION:
            raise ValueError(
                f"unsupported metrics_version {version!r} "
                f"(this reader supports {METRICS_VERSION})")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown RunMetrics fields: {sorted(unknown)}")
        return cls(**d)

    # -- construction helpers ------------------------------------------------

    @classmethod
    def from_tracer(cls, tracer, **fields: Any) -> "RunMetrics":
        """Build a metrics block with `phases`/`counters` taken from a
        Tracer's aggregates and everything else from explicit fields."""
        if tracer is not None:
            fields.setdefault("phases", tracer.phase_totals())
            fields.setdefault("counters", dict(tracer.counters))
            if "r_hat_trajectory" not in fields and "r_hat" in tracer.series:
                fields["r_hat_trajectory"] = tracer.series["r_hat"]
        return cls(**fields)
