"""Trace export: Chrome-trace/Perfetto JSON, JSONL, and the shared
strict-RFC artifact writer.

Chrome trace format (the `chrome://tracing` / Perfetto "JSON object"
flavor): a `{"traceEvents": [...]}` object whose events carry
microsecond `ts`/`dur`. Host-clock spans map 1 s -> 1e6 us as usual; sim
clock spans are scaled the same way (1 sim unit -> 1e6 us) so both load,
but land in separate Perfetto *processes* (pid 1 "host", pid 2 "sim") --
the two axes are different clocks and must never share a lane. Track
names become named threads via `thread_name` metadata events; counters
are emitted as one terminal `ph: "C"` sample per counter so totals show
up in the counter track.
"""

from __future__ import annotations

import json
import pathlib
from typing import Iterable

from repro.obs.tracer import TraceEvent, Tracer

__all__ = ["chrome_trace_events", "write_chrome_trace",
           "write_json_artifact", "write_jsonl"]

_CLOCK_PID = {"host": 1, "sim": 2}
_US = 1e6  # 1 second (or 1 sim unit) -> microseconds


def _track_ids(events: Iterable[TraceEvent]) -> dict[tuple[str, str], int]:
    """Stable (clock, track) -> tid assignment in first-seen order."""
    ids: dict[tuple[str, str], int] = {}
    for ev in events:
        key = (ev.clock, ev.track)
        if key not in ids:
            ids[key] = len(ids) + 1
    return ids


def chrome_trace_events(tracer: Tracer, run_name: str = "run") -> list[dict]:
    """Render a Tracer's events/counters as Chrome trace event dicts."""
    tids = _track_ids(tracer.events)
    out: list[dict] = []
    # process/thread naming metadata
    for clock, pid in _CLOCK_PID.items():
        label = "host (s)" if clock == "host" else "sim (units)"
        out.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                    "args": {"name": f"{run_name}: {label}"}})
    for (clock, track), tid in tids.items():
        out.append({"name": "thread_name", "ph": "M",
                    "pid": _CLOCK_PID[clock], "tid": tid,
                    "args": {"name": track}})
    for ev in tracer.events:
        pid = _CLOCK_PID[ev.clock]
        tid = tids[(ev.clock, ev.track)]
        if ev.instant:
            rec = {"name": ev.name, "ph": "i", "s": "t",
                   "ts": ev.t0 * _US, "pid": pid, "tid": tid}
        else:
            rec = {"name": ev.name, "ph": "X", "ts": ev.t0 * _US,
                   "dur": ev.dur * _US, "pid": pid, "tid": tid}
        if ev.args:
            rec["args"] = dict(ev.args)
        out.append(rec)
    # counter totals as one terminal sample each
    t_end = max((ev.t0 + ev.dur for ev in tracer.events), default=0.0)
    for name, value in sorted(tracer.counters.items()):
        out.append({"name": name, "ph": "C", "ts": t_end * _US,
                    "pid": _CLOCK_PID["host"], "tid": 0,
                    "args": {"value": value}})
    return out


def write_chrome_trace(tracer: Tracer, path, run_name: str = "run") -> str:
    """Write a Perfetto-loadable Chrome trace JSON file; returns the path."""
    payload = {
        "traceEvents": chrome_trace_events(tracer, run_name=run_name),
        "displayTimeUnit": "ms",
        "otherData": {
            "events_dropped": tracer.events_dropped,
            "series": {k: [[t, v] for t, v in s]
                       for k, s in tracer.series.items()},
        },
    }
    return write_json_artifact(path, payload)


def write_jsonl(tracer: Tracer, path) -> str:
    """Write the raw event stream as JSON Lines (one event per line,
    counters and series as trailing summary records); returns the path."""
    from repro.core.dda import json_sanitize

    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as f:
        for ev in tracer.events:
            rec = {"kind": "instant" if ev.instant else "span",
                   "name": ev.name, "t0": ev.t0, "dur": ev.dur,
                   "clock": ev.clock, "track": ev.track}
            if ev.args:
                rec["args"] = json_sanitize(ev.args)
            f.write(json.dumps(rec, allow_nan=False) + "\n")
        for name, value in sorted(tracer.counters.items()):
            f.write(json.dumps({"kind": "counter", "name": name,
                                "value": value}, allow_nan=False) + "\n")
        for name, samples in sorted(tracer.series.items()):
            f.write(json.dumps(
                {"kind": "series", "name": name,
                 "samples": [[t, v] for t, v in samples]},
                allow_nan=False) + "\n")
        if tracer.events_dropped:
            f.write(json.dumps({"kind": "dropped",
                                "count": tracer.events_dropped},
                               allow_nan=False) + "\n")
    return str(p)


def write_json_artifact(path, payload: dict) -> str:
    """The one strict-RFC JSON artifact writer: sanitizes (inf/nan ->
    null, np scalars -> Python), creates parent dirs, writes with
    `allow_nan=False`. CI smoke artifacts, bench --out files and the
    convergence tier's failure dumps all go through here."""
    from repro.core.dda import json_sanitize

    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as f:
        json.dump(json_sanitize(payload), f, indent=2, allow_nan=False)
    return str(p)
