"""Core: the paper's contribution -- consensus-based distributed optimization
with explicit communication/computation tradeoff control."""

from repro.core.graphs import (CommGraph, GraphSequence, build_graph,
                               complete_graph, expander_sequence,
                               hypercube_graph, kregular_expander, lambda2,
                               random_regular_expander, ring_graph,
                               spectral_gap, torus_graph)
from repro.core.schedules import (CommSchedule, EveryIteration,
                                  IncreasinglySparse, Periodic,
                                  PiecewisePeriodic, c1_constant,
                                  ch_constant, cp_constant, make_schedule,
                                  optimal_stepsize_A)
from repro.core.tradeoff import (TPU_V5E, HardwareSpec, derive_r_from_roofline,
                                 ew_alpha, ew_update, h_opt, h_opt_int,
                                 iteration_cost, lambda2_fast, measure_r,
                                 n_opt_complete, predict_speedup,
                                 time_to_accuracy)
from repro.core.consensus import (disagreement, mix_collective, mix_dense,
                                  mix_stale, stale_combine, stale_combine_batch,
                                  tree_mix_collective, tree_mix_dense)
from repro.core.dda import (DDASimulator, DDAState, SimTrace, dda_init,
                            dda_local_step, dda_mix_step, stepsize_sqrt)
from repro.core.compression import (CompressionState, ef_compress, ef_init,
                                    ratio_bytes, topk_compress,
                                    topk_decompress)
from repro.core.consensus_sgd import ConsensusConfig, mix_params, mix_params_dense
