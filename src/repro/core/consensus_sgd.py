"""[Beyond paper, anticipated by its section VI] Consensus wrapping of an
arbitrary inner optimizer (SGD / AdamW / ...).

The paper's closing remark proposes extending the analysis to stochastic
optimization where "h_t = t^p could correspond to using increasingly larger
minibatches". The modern form of that idea is local-update data parallelism
(DiLoCo-family): each consensus node runs `h` inner optimizer steps on its
shard, then the nodes gossip-average their PARAMETERS over the communication
graph G with mixing matrix P, on the paper's schedule.

This module provides the pure functions used by the production launcher:

    inner_step:  (params, opt_state, batch) -> (params, opt_state, metrics)
    mix_params:  params <- P params  (collective over the consensus axis)

Setting graph=complete and schedule=EveryIteration recovers exactly
synchronous data-parallel SGD on the gradients' average? -- no: parameter
averaging after each single step. For linear updates (plain SGD) the two are
IDENTICAL trajectories; tests/test_consensus_sgd.py verifies this equivalence
(gossip-DP == all-reduce-DP for SGD, h=1, complete graph), which is the
correctness anchor tying the consensus feature to standard DP.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import consensus as _cons
from repro.core.graphs import CommGraph

__all__ = ["ConsensusConfig", "mix_params", "mix_params_dense"]

PyTree = Any


class ConsensusConfig(NamedTuple):
    graph: CommGraph
    axis_name: str = "pod"


def mix_params(params: PyTree, cfg: ConsensusConfig) -> PyTree:
    """Gossip-average parameters over the consensus axis (inside shard_map)."""
    return _cons.tree_mix_collective(params, cfg.graph, cfg.axis_name)


def mix_params_dense(params_stack: PyTree, graph: CommGraph) -> PyTree:
    """Oracle/simulator version: leading axis = node index."""
    P = graph.mixing_matrix()
    return _cons.tree_mix_dense(params_stack, P)
