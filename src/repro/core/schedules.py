"""Communication schedules and convergence constants from the paper.

Three regimes (paper sections III.B, IV.A, IV.B):

  * every-iteration  (h = 1)                        -- constant C_1   (eq. 7)
  * periodic         (communicate every h+1 iters)  -- constant C_h   (eq. 18)
  * increasingly sparse (h_j = j^p, 0 < p < 1/2)    -- constant C_p   (eq. 31)

A schedule answers one question per step t (1-indexed): "is t a communication
(expensive) iteration?" plus the bookkeeping H_t (number of communication
steps among the first t iterations, eq. 12) and Q_t (iterations since the last
communication).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator

import numpy as np

__all__ = [
    "CommSchedule",
    "EveryIteration",
    "Periodic",
    "IncreasinglySparse",
    "make_schedule",
    "c1_constant",
    "ch_constant",
    "cp_constant",
    "optimal_stepsize_A",
]


class CommSchedule:
    """Base class. Iterations are 1-indexed, matching the paper."""

    name: str = "base"

    def is_comm_step(self, t: int) -> bool:
        raise NotImplementedError

    def H(self, t: int) -> int:
        """Number of communication steps among iterations 1..t."""
        return sum(1 for s in range(1, t + 1) if self.is_comm_step(s))

    def comm_steps(self, T: int) -> Iterator[int]:
        return (t for t in range(1, T + 1) if self.is_comm_step(t))

    def next_comm_step(self, t: int) -> int:
        """Smallest communication iteration strictly greater than t.

        Sim-time query used by the event-driven netsim: an async node asks
        once per communication round instead of testing `is_comm_step`
        every iteration (which is O(t) per call for the sparse schedule).
        Subclasses override with closed forms where available.
        """
        s = t + 1
        while not self.is_comm_step(s):
            s += 1
        return s

    def next_comm_step_batch(self, t: np.ndarray) -> np.ndarray:
        """`next_comm_step` over an int array of iteration counters.

        Used by the netsim's vectorized engine, which advances a whole
        batch of due nodes per event bucket. The base implementation is
        the per-element loop; schedules with closed forms override it with
        pure array arithmetic so a 1000-node batch costs no Python-level
        iteration.
        """
        t = np.asarray(t)
        return np.array([self.next_comm_step(int(s)) for s in t],
                        dtype=np.int64)

    def constant(self, L: float, R: float, lam2: float) -> float:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class EveryIteration(CommSchedule):
    """h = 1: communicate at every iteration (original DDA, paper III.B)."""

    name: str = "every"

    def is_comm_step(self, t: int) -> bool:
        return True

    def H(self, t: int) -> int:
        return t

    def next_comm_step(self, t: int) -> int:
        return t + 1

    def next_comm_step_batch(self, t: np.ndarray) -> np.ndarray:
        return np.asarray(t, dtype=np.int64) + 1

    def constant(self, L: float, R: float, lam2: float) -> float:
        return c1_constant(L, R, lam2)


@dataclasses.dataclass(frozen=True)
class Periodic(CommSchedule):
    """Communicate once every h+1 iterations (h cheap then 1 expensive).

    Paper IV.A: of T iterations only H_T = floor((T-1)/h) involve
    communication (eq. 19). We realize that count with comm steps at
    t = h+1, 2h+2, ...? No -- the paper's indexing has the FIRST h
    iterations cheap, then iteration h+1 is... Careful reading of eq. (12):
    H_t = floor((t-1)/h) counts communication steps within t iterations and
    Q_t = mod(t, h) (or h when the mod is 0) counts the trailing cheap
    iterations. That corresponds to: iteration t is expensive iff
    t ≡ 1 (mod h) and t > 1  -- i.e. comm happens at t = h+1, 2h+1, 3h+1...
    equivalently after every h local updates.
    """

    h: int = 1
    name: str = "periodic"

    def __post_init__(self):
        if self.h < 1:
            raise ValueError("h must be >= 1")

    def is_comm_step(self, t: int) -> bool:
        return t > 1 and (t - 1) % self.h == 0

    def H(self, t: int) -> int:
        return (t - 1) // self.h

    def Q(self, t: int) -> int:
        m = t % self.h
        return m if m > 0 else self.h

    def next_comm_step(self, t: int) -> int:
        # comm steps are 1 + m*h for m >= 1
        m = max(1, (t - 1) // self.h + 1)
        return 1 + m * self.h

    def next_comm_step_batch(self, t: np.ndarray) -> np.ndarray:
        t = np.asarray(t, dtype=np.int64)
        m = np.maximum(1, (t - 1) // self.h + 1)
        return 1 + m * self.h

    def constant(self, L: float, R: float, lam2: float) -> float:
        return ch_constant(L, R, lam2, self.h)


@dataclasses.dataclass(frozen=True)
class IncreasinglySparse(CommSchedule):
    """h_j = j^p cheap-iteration gaps (paper IV.B).

    The j-th communication happens at iteration ceil(sum_{i<=j} i^p): the
    first at h_1 = 1, the second at h_1 + h_2, etc. H_T = Theta(T^(1/(p+1)))
    communication steps among T iterations (eq. 22). Convergence requires
    0 <= p < 1/2 (p = 1 provably diverges -- paper Fig. 2).
    """

    p: float = 0.3
    name: str = "sparse"

    def __post_init__(self):
        if self.p < 0:
            raise ValueError("p must be >= 0")

    def _comm_times(self, upto: int) -> list[int]:
        times, acc, j = [], 0.0, 1
        while True:
            acc += j ** self.p
            t = math.ceil(acc)
            if t > upto:
                break
            times.append(t)
            j += 1
        return times

    def is_comm_step(self, t: int) -> bool:
        # t is a comm step iff exists j with ceil(sum_{i<=j} i^p) == t.
        acc, j = 0.0, 1
        while True:
            acc += j ** self.p
            ct = math.ceil(acc)
            if ct == t:
                return True
            if ct > t:
                return False
            j += 1

    def H(self, t: int) -> int:
        return len(self._comm_times(t))

    def next_comm_step(self, t: int) -> int:
        acc, j = 0.0, 1
        while True:
            acc += j ** self.p
            ct = math.ceil(acc)
            if ct > t:
                return ct
            j += 1

    def constant(self, L: float, R: float, lam2: float) -> float:
        return cp_constant(L, R, lam2, self.p)


def make_schedule(kind: str, *, h: int = 1, p: float = 0.3) -> CommSchedule:
    if kind in ("every", "h1"):
        return EveryIteration()
    if kind == "periodic":
        return Periodic(h=h)
    if kind == "sparse":
        return IncreasinglySparse(p=p)
    raise ValueError(f"unknown schedule {kind!r}")


# ---------------------------------------------------------------------------
# Convergence-rate leading constants (all with a(t) = A / sqrt(t), optimized A)
# ---------------------------------------------------------------------------

def c1_constant(L: float, R: float, lam2: float) -> float:
    """C_1 = 2LR sqrt(19 + 12/(1 - sqrt(lam2)))  -- eq. (7)."""
    gap = 1.0 - math.sqrt(min(max(lam2, 0.0), 1.0 - 1e-15))
    return 2.0 * L * R * math.sqrt(19.0 + 12.0 / gap)


def ch_constant(L: float, R: float, lam2: float, h: int) -> float:
    """C_h = 2RL sqrt(1 + 18h + 12h/(1 - sqrt(lam2)))  -- eq. (18)."""
    gap = 1.0 - math.sqrt(min(max(lam2, 0.0), 1.0 - 1e-15))
    return 2.0 * R * L * math.sqrt(1.0 + 18.0 * h + 12.0 * h / gap)


def cp_constant(L: float, R: float, lam2: float, p: float) -> float:
    """C_p = 2LR sqrt(7 + (12p+12)/((3p+1)(1-sqrt(lam2))) + 12/(2p+1)) -- eq. (31)."""
    gap = 1.0 - math.sqrt(min(max(lam2, 0.0), 1.0 - 1e-15))
    return 2.0 * L * R * math.sqrt(
        7.0 + (12.0 * p + 12.0) / ((3.0 * p + 1.0) * gap) + 12.0 / (2.0 * p + 1.0)
    )


def optimal_stepsize_A(L: float, R: float, lam2: float, h: int = 1) -> float:
    """A = (R/L) / sqrt(1 + 18h + 12h/(1-sqrt(lam2)))  -- eq. (18)."""
    gap = 1.0 - math.sqrt(min(max(lam2, 0.0), 1.0 - 1e-15))
    return (R / L) / math.sqrt(1.0 + 18.0 * h + 12.0 * h / gap)
