"""Communication schedules and convergence constants from the paper.

Three regimes (paper sections III.B, IV.A, IV.B):

  * every-iteration  (h = 1)                        -- constant C_1   (eq. 7)
  * periodic         (communicate every h+1 iters)  -- constant C_h   (eq. 18)
  * increasingly sparse (h_j = j^p, 0 < p < 1/2)    -- constant C_p   (eq. 31)

A schedule answers one question per step t (1-indexed): "is t a communication
(expensive) iteration?" plus the bookkeeping H_t (number of communication
steps among the first t iterations, eq. 12) and Q_t (iterations since the last
communication).
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Iterator

import numpy as np

__all__ = [
    "CommSchedule",
    "EveryIteration",
    "Periodic",
    "IncreasinglySparse",
    "PiecewisePeriodic",
    "make_schedule",
    "c1_constant",
    "ch_constant",
    "cp_constant",
    "optimal_stepsize_A",
]


class CommSchedule:
    """Base class. Iterations are 1-indexed, matching the paper."""

    name: str = "base"

    def is_comm_step(self, t: int) -> bool:
        raise NotImplementedError

    def H(self, t: int) -> int:
        """Number of communication steps among iterations 1..t."""
        return sum(1 for s in range(1, t + 1) if self.is_comm_step(s))

    def comm_steps(self, T: int) -> Iterator[int]:
        return (t for t in range(1, T + 1) if self.is_comm_step(t))

    def next_comm_step(self, t: int) -> int:
        """Smallest communication iteration strictly greater than t.

        Sim-time query used by the event-driven netsim: an async node asks
        once per communication round instead of testing `is_comm_step`
        every iteration (which is O(t) per call for the sparse schedule).
        Subclasses override with closed forms where available.
        """
        s = t + 1
        while not self.is_comm_step(s):
            s += 1
        return s

    def next_comm_step_batch(self, t: np.ndarray) -> np.ndarray:
        """`next_comm_step` over an int array of iteration counters.

        Used by the netsim's vectorized engine, which advances a whole
        batch of due nodes per event bucket. The base implementation is
        the per-element loop; schedules with closed forms override it with
        pure array arithmetic so a 1000-node batch costs no Python-level
        iteration.
        """
        t = np.asarray(t)
        return np.array([self.next_comm_step(int(s)) for s in t],
                        dtype=np.int64)

    def comm_mask(self, t0: int, length: int) -> np.ndarray:
        """Boolean mask over iterations t0+1 .. t0+length: True where the
        iteration communicates.

        This is the whole-run precompute behind `DDASimulator`'s scanned
        segment loop: the comm pattern becomes DATA fed to one compiled
        program instead of a host-side `is_comm_step` query per iteration
        per dispatch. The base implementation hops `next_comm_step`
        (O(#comm steps), schedule-agnostic); Every/Periodic/Sparse/
        Piecewise override with pure array arithmetic.
        """
        mask = np.zeros(int(length), dtype=bool)
        t = int(t0)
        end = int(t0) + int(length)
        while True:
            t = self.next_comm_step(t)
            if t > end:
                return mask
            mask[t - t0 - 1] = True

    def constant(self, L: float, R: float, lam2: float) -> float:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class EveryIteration(CommSchedule):
    """h = 1: communicate at every iteration (original DDA, paper III.B)."""

    name: str = "every"

    def is_comm_step(self, t: int) -> bool:
        return True

    def H(self, t: int) -> int:
        return t

    def next_comm_step(self, t: int) -> int:
        return t + 1

    def next_comm_step_batch(self, t: np.ndarray) -> np.ndarray:
        return np.asarray(t, dtype=np.int64) + 1

    def comm_mask(self, t0: int, length: int) -> np.ndarray:
        return np.ones(int(length), dtype=bool)

    def constant(self, L: float, R: float, lam2: float) -> float:
        return c1_constant(L, R, lam2)


@dataclasses.dataclass(frozen=True)
class Periodic(CommSchedule):
    """Communicate once every h+1 iterations (h cheap then 1 expensive).

    Paper IV.A: of T iterations only H_T = floor((T-1)/h) involve
    communication (eq. 19). We realize that count with comm steps at
    t = h+1, 2h+2, ...? No -- the paper's indexing has the FIRST h
    iterations cheap, then iteration h+1 is... Careful reading of eq. (12):
    H_t = floor((t-1)/h) counts communication steps within t iterations and
    Q_t = mod(t, h) (or h when the mod is 0) counts the trailing cheap
    iterations. That corresponds to: iteration t is expensive iff
    t ≡ 1 (mod h) and t > 1  -- i.e. comm happens at t = h+1, 2h+1, 3h+1...
    equivalently after every h local updates.
    """

    h: int = 1
    name: str = "periodic"

    def __post_init__(self):
        if self.h < 1:
            raise ValueError("h must be >= 1")

    def is_comm_step(self, t: int) -> bool:
        return t > 1 and (t - 1) % self.h == 0

    def H(self, t: int) -> int:
        return (t - 1) // self.h

    def Q(self, t: int) -> int:
        m = t % self.h
        return m if m > 0 else self.h

    def next_comm_step(self, t: int) -> int:
        # comm steps are 1 + m*h for m >= 1
        m = max(1, (t - 1) // self.h + 1)
        return 1 + m * self.h

    def next_comm_step_batch(self, t: np.ndarray) -> np.ndarray:
        t = np.asarray(t, dtype=np.int64)
        m = np.maximum(1, (t - 1) // self.h + 1)
        return 1 + m * self.h

    def comm_mask(self, t0: int, length: int) -> np.ndarray:
        t = np.arange(int(t0) + 1, int(t0) + int(length) + 1, dtype=np.int64)
        return (t > 1) & ((t - 1) % self.h == 0)

    def constant(self, L: float, R: float, lam2: float) -> float:
        return ch_constant(L, R, lam2, self.h)


@dataclasses.dataclass(frozen=True)
class IncreasinglySparse(CommSchedule):
    """h_j = j^p cheap-iteration gaps (paper IV.B).

    The j-th communication happens at iteration ceil(sum_{i<=j} i^p): the
    first at h_1 = 1, the second at h_1 + h_2, etc. H_T = Theta(T^(1/(p+1)))
    communication steps among T iterations (eq. 22). Convergence requires
    0 <= p < 1/2 (p = 1 provably diverges -- paper Fig. 2).
    """

    p: float = 0.3
    name: str = "sparse"

    def __post_init__(self):
        if self.p < 0:
            raise ValueError("p must be >= 0")

    def _comm_times(self, upto: int) -> list[int]:
        times, acc, j = [], 0.0, 1
        while True:
            acc += j ** self.p
            t = math.ceil(acc)
            if t > upto:
                break
            times.append(t)
            j += 1
        return times

    def _comm_times_past(self, upto: int) -> np.ndarray:
        """All comm times for j = 1..jmax with jmax chosen so the tail
        strictly exceeds `upto` (sum_{i<=j} i^p >= j^(p+1)/(p+1), so any
        j > ((p+1) upto)^(1/(p+1)) lands past it). The partial sums are
        accumulated with host floats in the exact order of the scalar
        queries above, so the vectorized answers can never drift from
        `is_comm_step`/`next_comm_step` by a ulp of `pow`."""
        upto = max(int(upto), 1)
        jmax = int(((self.p + 1.0) * upto) ** (1.0 / (self.p + 1.0))) + 2
        steps = np.array([float(j) ** self.p for j in range(1, jmax + 1)],
                         dtype=np.float64)
        times = np.ceil(np.cumsum(steps)).astype(np.int64)
        assert times[-1] > upto, (times[-1], upto)
        return times

    def is_comm_step(self, t: int) -> bool:
        # t is a comm step iff exists j with ceil(sum_{i<=j} i^p) == t.
        acc, j = 0.0, 1
        while True:
            acc += j ** self.p
            ct = math.ceil(acc)
            if ct == t:
                return True
            if ct > t:
                return False
            j += 1

    def H(self, t: int) -> int:
        return len(self._comm_times(t))

    def next_comm_step(self, t: int) -> int:
        acc, j = 0.0, 1
        while True:
            acc += j ** self.p
            ct = math.ceil(acc)
            if ct > t:
                return ct
            j += 1

    def next_comm_step_batch(self, t: np.ndarray) -> np.ndarray:
        """Vectorized closed form: the comm times are the ceil'd partial
        sums of j^p, so 'first comm step strictly after t' is one
        searchsorted into that (precomputed) sequence -- no per-element
        Python iteration, usable inside the scanned-mask precompute."""
        t = np.asarray(t, dtype=np.int64)
        times = self._comm_times_past(int(t.max()) if t.size else 1)
        return times[np.searchsorted(times, t, side="right")]

    def comm_mask(self, t0: int, length: int) -> np.ndarray:
        t0, length = int(t0), int(length)
        mask = np.zeros(length, dtype=bool)
        times = self._comm_times_past(t0 + length)
        sel = times[(times > t0) & (times <= t0 + length)]
        mask[sel - t0 - 1] = True
        return mask

    def constant(self, L: float, R: float, lam2: float) -> float:
        return cp_constant(L, R, lam2, self.p)


class PiecewisePeriodic(CommSchedule):
    """Periodic schedule whose interval h can be re-spliced forward in time.

    This is the schedule-mutation protocol the closed-loop controller
    (`repro.adaptive.AdaptiveSchedule`) builds on: the comm pattern is a
    sequence of segments, each a plain `Periodic`-style pattern

        comm steps of segment j:  t = a_j + m * h_j   (m >= 1, s_j < t <= e_j)

    where `s_j` is the segment's start iteration, `e_j` the next segment's
    start (inf for the last), and `a_j` the ANCHOR -- the last communication
    step at or before `s_j` (1 before any communication has happened, so a
    fresh instance with one segment reproduces `Periodic(h)` exactly,
    including the t > 1 rule). Anchoring each splice at the previous comm
    step preserves the "h cheap iterations between communications"
    semantics across an h change instead of resetting the phase.

    Mutation contract (`set_h`):
      * append-only in time: `from_t` must be >= the latest segment start;
        the pattern for iterations <= `from_t` NEVER changes, so answers
        already handed out for past iterations stay valid.
      * re-splicing at the same `from_t` replaces the pending segment.
      * after any sequence of mutations the schedule is still a fixed
        deterministic sequence: `H(t)` is non-decreasing,
        `next_comm_step(t) > t`, and the batch query agrees with the
        scalar path (property-tested in tests/test_adaptive.py).

    All queries are closed-form per segment (no per-iteration scanning):
    `H` and `next_comm_step` cost O(log #segments) and
    `next_comm_step_batch` is pure array arithmetic plus at most one
    segment-advance round per distinct segment touched -- the C_h/C_p
    bookkeeping stays cheap for the vectorized engine's batch queries.
    """

    name: str = "piecewise"

    def __init__(self, h: int = 1):
        if h < 1:
            raise ValueError("h must be >= 1")
        self._h0 = int(h)
        self.reset()

    def reset(self) -> None:
        """Discard every splice and return to the initial single-segment
        pattern -- the 'new run, fresh history' hook (a fixed run's past is
        immutable, but a NEW run starts its own timeline; the controller's
        bind() calls this)."""
        # parallel arrays: segment start, interval, anchor, H(start)
        self._starts = [0]
        self._hs = [self._h0]
        self._anchors = [1]
        self._H0 = [0]

    # -- mutation protocol ---------------------------------------------------

    @property
    def h_current(self) -> int:
        """Interval of the latest segment (the one future splices extend)."""
        return self._hs[-1]

    @property
    def segments(self) -> list[tuple[int, int]]:
        """[(start, h), ...] -- the splice history, for diagnostics."""
        return list(zip(self._starts, self._hs))

    def set_h(self, from_t: int, h: int) -> None:
        """Splice a new interval: iterations > from_t follow `h`.

        `from_t` must be at or beyond the latest existing splice point
        (append-only; the past is immutable). Callers that drive live runs
        pass the node-iteration frontier (max in-flight iteration), so no
        already-made communication decision is ever rewritten.
        """
        from_t, h = int(from_t), int(h)
        if h < 1:
            raise ValueError("h must be >= 1")
        if from_t < self._starts[-1]:
            raise ValueError(
                f"splice at {from_t} is before the latest segment start "
                f"{self._starts[-1]} (mutations are append-only in time)")
        if from_t == self._starts[-1]:
            # replace the pending segment (same start => same anchor/H0)
            self._hs[-1] = h
            return
        if h == self._hs[-1]:
            return  # no-op splice
        j = len(self._starts) - 1
        a, hj = self._anchors[j], self._hs[j]
        anchor = a + hj * ((from_t - a) // hj)  # last comm step <= from_t
        self._starts.append(from_t)
        self._hs.append(h)
        self._anchors.append(anchor)
        self._H0.append(self.H(from_t))

    # -- queries (closed forms per segment) ----------------------------------

    def _seg(self, t: int) -> int:
        """Index of the segment containing iteration t (t > start)."""
        return max(bisect.bisect_left(self._starts, t) - 1, 0)

    def is_comm_step(self, t: int) -> bool:
        if t <= 1:
            return False
        j = self._seg(t)
        a = self._anchors[j]
        return t > a and (t - a) % self._hs[j] == 0

    def H(self, t: int) -> int:
        if t <= 1:
            return 0
        j = self._seg(t)
        s, h, a = self._starts[j], self._hs[j], self._anchors[j]
        return self._H0[j] + (t - a) // h - max(s - a, 0) // h

    def next_comm_step(self, t: int) -> int:
        j = self._seg(max(t, 1))
        while True:
            s, h, a = self._starts[j], self._hs[j], self._anchors[j]
            end = (self._starts[j + 1] if j + 1 < len(self._starts)
                   else None)
            base = max(t, s)
            cand = a + h * max((base - a) // h + 1, 1)
            if end is None or cand <= end:
                return cand
            j += 1

    def comm_mask(self, t0: int, length: int) -> np.ndarray:
        """Vectorized `is_comm_step` over one iteration window: resolve
        every iteration's segment with one searchsorted, then apply each
        segment's anchored modulus -- pure array arithmetic regardless of
        how many splices the controller has appended."""
        t = np.arange(int(t0) + 1, int(t0) + int(length) + 1, dtype=np.int64)
        starts = np.asarray(self._starts, dtype=np.int64)
        hs = np.asarray(self._hs, dtype=np.int64)
        anchors = np.asarray(self._anchors, dtype=np.int64)
        j = np.maximum(np.searchsorted(starts, t, side="left") - 1, 0)
        a = anchors[j]
        return (t > 1) & (t > a) & ((t - a) % hs[j] == 0)

    def next_comm_step_batch(self, t: np.ndarray) -> np.ndarray:
        t = np.asarray(t, dtype=np.int64)
        starts = np.asarray(self._starts, dtype=np.int64)
        hs = np.asarray(self._hs, dtype=np.int64)
        anchors = np.asarray(self._anchors, dtype=np.int64)
        # segment ends; sentinel keeps every candidate in the last segment
        ends = np.concatenate([starts[1:], [np.iinfo(np.int64).max]])
        j = np.maximum(np.searchsorted(starts, np.maximum(t, 1),
                                       side="left") - 1, 0)
        last = len(starts) - 1
        while True:
            a, h = anchors[j], hs[j]
            base = np.maximum(t, starts[j])
            cand = a + h * np.maximum((base - a) // h + 1, 1)
            over = (cand > ends[j]) & (j < last)
            if not over.any():
                return cand
            j = j + over  # advance the overshooting rows one segment

    def constant(self, L: float, R: float, lam2: float) -> float:
        """Convergence constant of the CURRENT interval (eq. 18). A spliced
        run's true constant is segment-dependent; this is the controller's
        working value for the pattern it is emitting now."""
        return ch_constant(L, R, lam2, self.h_current)


def make_schedule(kind: str, *, h: int | None = None,
                  p: float | None = None, **kwargs) -> CommSchedule:
    """Build a schedule by kind -- a thin shim over the
    `repro.experiments.components.schedules` registry.

    The ad-hoc kind branching that used to live here is deprecated: it
    could not construct `PiecewisePeriodic` (or `repro.adaptive`'s
    AdaptiveSchedule), and every new schedule needed an edit in two places.
    Now the registry is the single source of kinds ("every"/"h1",
    "periodic", "sparse", "piecewise", "adaptive", ...). This function only
    preserves the legacy calling convention: callers may pass both `h` and
    `p` and each kind takes what it accepts (`make_schedule("every",
    h=args.h)` stays legal, as the benchmark CLIs rely on), with the
    registry builders' own defaults (h=1, p=0.3) when omitted. Any OTHER
    kwarg is forwarded verbatim, so typos fail loudly. New code should use
    the registry (or an ExperimentSpec schedule component) directly.
    """
    from repro.experiments.components import schedules as _registry
    try:
        name = _registry.canonical(kind)
    except KeyError as e:  # legacy contract: unknown kind is a ValueError
        raise ValueError(str(e)) from None
    legacy = {}
    if h is not None:
        legacy["h"] = h
    if p is not None:
        legacy["p"] = p
    legacy = _registry.accepted(name, legacy)
    return _registry.build(name, **legacy, **kwargs)


# ---------------------------------------------------------------------------
# Convergence-rate leading constants (all with a(t) = A / sqrt(t), optimized A)
# ---------------------------------------------------------------------------

def c1_constant(L: float, R: float, lam2: float) -> float:
    """C_1 = 2LR sqrt(19 + 12/(1 - sqrt(lam2)))  -- eq. (7)."""
    gap = 1.0 - math.sqrt(min(max(lam2, 0.0), 1.0 - 1e-15))
    return 2.0 * L * R * math.sqrt(19.0 + 12.0 / gap)


def ch_constant(L: float, R: float, lam2: float, h: int) -> float:
    """C_h = 2RL sqrt(1 + 18h + 12h/(1 - sqrt(lam2)))  -- eq. (18)."""
    gap = 1.0 - math.sqrt(min(max(lam2, 0.0), 1.0 - 1e-15))
    return 2.0 * R * L * math.sqrt(1.0 + 18.0 * h + 12.0 * h / gap)


def cp_constant(L: float, R: float, lam2: float, p: float) -> float:
    """C_p = 2LR sqrt(7 + (12p+12)/((3p+1)(1-sqrt(lam2))) + 12/(2p+1)) -- eq. (31)."""
    gap = 1.0 - math.sqrt(min(max(lam2, 0.0), 1.0 - 1e-15))
    return 2.0 * L * R * math.sqrt(
        7.0 + (12.0 * p + 12.0) / ((3.0 * p + 1.0) * gap) + 12.0 / (2.0 * p + 1.0)
    )


def optimal_stepsize_A(L: float, R: float, lam2: float, h: int = 1) -> float:
    """A = (R/L) / sqrt(1 + 18h + 12h/(1-sqrt(lam2)))  -- eq. (18)."""
    gap = 1.0 - math.sqrt(min(max(lam2, 0.0), 1.0 - 1e-15))
    return (R / L) / math.sqrt(1.0 + 18.0 * h + 12.0 * h / gap)
