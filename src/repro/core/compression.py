"""[Beyond paper] Message compression for consensus exchanges.

The paper's tradeoff parameter r is (message time)/(gradient time). Top-k
sparsification with error feedback shrinks message bytes by the compression
ratio c, hence r -> r*c, which moves the paper's optima:

    n_opt = 1/sqrt(r c)     (eq. 11, larger optimal cluster)
    h_opt ~ sqrt(n k r c)   (eq. 21, communicate more often again)

Error feedback (memory of the residual) keeps the consensus average unbiased
over time and is required for convergence with biased compressors.

This module is the seed-era flat-vector API, kept for back-compat; the
full subsystem -- the compressor registry (`topk`/`randk`/`int8`/`none`),
the per-message byte models, and the numpy halves the netsim engines
consume -- lives in `repro.compress`, and every top-k support computation
here routes through its one exact-k implementation
(`repro.compress.topk_indices_flat`), so the flat API and the simulators
can never disagree on tie handling again.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.compress.base import (INDEX_BYTES, VALUE_BYTES,
                                 topk_indices_flat)

__all__ = ["CompressionState", "topk_compress", "topk_decompress",
           "ef_init", "ef_compress", "ratio_bytes"]

PyTree = Any


class CompressionState(NamedTuple):
    residual: PyTree  # error-feedback memory, same structure as the message


def topk_compress(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Return (values, flat indices) of the k largest-magnitude entries.
    Exactly k even on magnitude ties (shared exact-k implementation)."""
    flat = x.reshape(-1)
    k = min(k, flat.shape[0])
    idx = topk_indices_flat(flat, k)
    return flat[idx], idx


def topk_decompress(values: jax.Array, idx: jax.Array, shape) -> jax.Array:
    out = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), values.dtype)
    out = out.at[idx].set(values)
    return out.reshape(shape)


def ef_init(msg_like: PyTree) -> CompressionState:
    return CompressionState(residual=jax.tree.map(jnp.zeros_like, msg_like))


def ef_compress(msg: PyTree, state: CompressionState,
                keep_fraction: float = 0.01) -> tuple[PyTree, CompressionState]:
    """Error-feedback top-k: compress (msg + residual), remember what was
    dropped. Returns (sparse-but-dense-layout message, new state); the dense
    layout keeps downstream mixing code unchanged while bytes-on-wire are
    counted via `ratio_bytes`."""

    def one(m, res):
        corrected = m + res
        k = max(1, int(corrected.size * keep_fraction))
        vals, idx = topk_compress(corrected, k)
        sent = topk_decompress(vals, idx, corrected.shape)
        return sent, corrected - sent

    flat_m, treedef = jax.tree.flatten(msg)
    flat_r = jax.tree.leaves(state.residual)
    sent_res = [one(m, r) for m, r in zip(flat_m, flat_r)]
    sent = jax.tree.unflatten(treedef, [s for s, _ in sent_res])
    resid = jax.tree.unflatten(treedef, [r for _, r in sent_res])
    return sent, CompressionState(residual=resid)


def ratio_bytes(keep_fraction: float, dtype_bytes: int = VALUE_BYTES,
                index_bytes: int = INDEX_BYTES) -> float:
    """Bytes-on-wire ratio of top-k vs dense (values + indices). The
    per-compressor generalization -- rand-k's index-free wire format,
    int8's codes+scale -- is `Compressor.wire_ratio` in `repro.compress`."""
    return keep_fraction * (dtype_bytes + index_bytes) / dtype_bytes
