"""Consensus mixing operators: z <- P z over the communication graph.

Two device realizations plus a host oracle:

  * `mix_dense`      -- oracle: stacked z of shape (n, ...) times the dense
                        doubly-stochastic P. Used by the single-process
                        simulator (paper experiments) and as the test oracle.
  * `mix_collective` -- inside `shard_map`: complete graph -> `lax.pmean`
                        (one all-reduce); k-regular graph -> k
                        `lax.ppermute`s + weighted accumulation. This is the
                        TPU-native mapping of the paper's point-to-point
                        messages (DESIGN.md section 2).
  * `mix_stale`      -- [beyond paper] one-step-stale (async) gossip: mixes
                        with the PREVIOUS round's neighbor values while
                        shipping the current ones, so the permute latency
                        overlaps the next local step.

All operators are linear and preserve the network average exactly (P is
doubly stochastic) -- property-tested in tests/test_consensus.py.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graphs import CommGraph

__all__ = [
    "mix_dense",
    "mix_collective",
    "mix_stale",
    "stale_combine",
    "stale_combine_batch",
    "tree_mix_dense",
    "tree_mix_collective",
    "disagreement",
]

PyTree = Any


def mix_dense(z: jax.Array, P: jax.Array | np.ndarray) -> jax.Array:
    """Oracle mixing: z has shape (n, ...) -- one leading row per node."""
    P = jnp.asarray(P, dtype=z.dtype)
    zf = z.reshape(z.shape[0], -1)
    return (P @ zf).reshape(z.shape)


def tree_mix_dense(tree: PyTree, P: jax.Array | np.ndarray) -> PyTree:
    return jax.tree.map(lambda a: mix_dense(a, P), tree)


def _ppermute_accumulate(z: jax.Array, graph: CommGraph, axis_name: str,
                         *, self_weight: float | None = None,
                         edge_weight: float | None = None) -> jax.Array:
    sw = graph.self_weight if self_weight is None else self_weight
    ew = graph.edge_weight if edge_weight is None else edge_weight
    acc = z * sw
    for pairs in graph.ppermute_pairs():
        recv = jax.lax.ppermute(z, axis_name, perm=list(pairs))
        acc = acc + ew * recv
    return acc


def mix_collective(z: jax.Array, graph: CommGraph, axis_name: str) -> jax.Array:
    """Mixing inside shard_map over `axis_name` (one node per axis index).

    Complete graph: P = (1/n) 11^T, i.e. exact averaging -> single pmean
    (an all-reduce; on TPU this is the native ICI collective and is both
    faster and numerically exact vs. n-1 permutes).
    k-regular: k ppermutes + weighted accumulation.
    """
    if graph.name == "complete":
        return jax.lax.pmean(z, axis_name)
    return _ppermute_accumulate(z, graph, axis_name)


def tree_mix_collective(tree: PyTree, graph: CommGraph, axis_name: str) -> PyTree:
    return jax.tree.map(lambda a: mix_collective(a, graph, axis_name), tree)


def stale_combine(z, neighbor_acc, self_weight: float):
    """Stale-gossip combine: self_weight * z + (edge-weighted sum of the
    neighbor values that actually arrived). Shared by the shard_map
    `mix_stale` below and by `repro.netsim.node.AsyncDDANode`, whose
    event-driven nodes fold the weight of missing/late messages back into
    `self_weight` (row-stochasticity preserved, as in
    runtime.fault_tolerance.degraded_matrix). Works on jax and numpy arrays.
    """
    return z * self_weight + neighbor_acc


def stale_combine_batch(z_stack, neighbor_acc_stack, self_weights):
    """`stale_combine` over a stacked batch of nodes at once.

    z_stack / neighbor_acc_stack have shape (b, ...); `self_weights` is a
    (b,) vector because each node folds a DIFFERENT number of undelivered
    in-neighbors back into its own weight. Elementwise it is the exact same
    arithmetic as b scalar `stale_combine` calls -- the netsim's vectorized
    engine relies on that for bit-identical traces against the per-node
    object engine. Works on jax and numpy arrays.
    """
    sw = self_weights.reshape(self_weights.shape[0],
                              *([1] * (z_stack.ndim - 1)))
    return z_stack * sw + neighbor_acc_stack


def mix_stale(z: jax.Array, neighbor_acc: jax.Array, graph: CommGraph,
              axis_name: str) -> tuple[jax.Array, jax.Array]:
    """[beyond paper] async gossip: returns (mixed, next_neighbor_acc).

    `neighbor_acc` is the edge-weighted sum of neighbor values shipped during
    the PREVIOUS round (already multiplied by edge_weight). The mixed value
    uses those stale messages; fresh messages for the next round are launched
    now, so their transfer overlaps the subsequent local computation. One-step
    delay preserves DDA convergence (paper ref [9], delay-tolerant DDA).
    """
    mixed = stale_combine(z, neighbor_acc, graph.self_weight)
    # Ship current z to neighbors for the NEXT round.
    nxt = jnp.zeros_like(z)
    if graph.name == "complete":
        n = graph.n
        # pmean of z minus own contribution, scaled to edge weights (1/n each).
        nxt = jax.lax.pmean(z, axis_name) - z / n
    else:
        for pairs in graph.ppermute_pairs():
            nxt = nxt + graph.edge_weight * jax.lax.ppermute(z, axis_name, perm=list(pairs))
    return mixed, nxt


def disagreement(z_stack: jax.Array) -> jax.Array:
    """Network error max_i ||z_bar - z_i|| (paper's network-error term in
    eq. (6)); z_stack has shape (n, ...)."""
    zbar = jnp.mean(z_stack, axis=0, keepdims=True)
    diff = (z_stack - zbar).reshape(z_stack.shape[0], -1)
    return jnp.max(jnp.linalg.norm(diff, axis=-1))
