"""Communication graph topologies for consensus-based distributed optimization.

The paper (Tsianos, Lawlor, Rabbat 2012) studies DDA over a user-defined
communication graph G = (V, E) with a doubly-stochastic mixing matrix P whose
second-largest eigenvalue magnitude lambda_2 controls the convergence constant
C_1 = 2LR * sqrt(19 + 12 / (1 - sqrt(lambda_2)))          (eq. 7).

Everything here is *host-side* (numpy): the n x n matrix P is never shipped to
device. Devices see only the per-edge structure (`shift_edges`) which maps each
graph edge set onto `jax.lax.ppermute` permutations -- the TPU-native
realization of point-to-point messages.

Design notes
------------
* All graphs are built as **circulant** graphs where possible (ring, complete,
  hypercube-on-ring, expanders via quadratic-residue / chordal shifts). A
  circulant edge set {±s_1, ..., ±s_k} means every mixing round is a set of
  uniform-shift ppermutes -- the cheapest collective pattern on an ICI torus.
* Mixing weights: lazy Metropolis / max-degree uniform weights
  P = I - (L_G / (k+1)) for k-regular G, which is symmetric doubly stochastic
  with p_ij = 1/(k+1) on edges (including self-loop weight 1/(k+1)).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "CommGraph",
    "GraphSequence",
    "complete_graph",
    "ring_graph",
    "torus_graph",
    "hypercube_graph",
    "kregular_expander",
    "random_regular_expander",
    "expander_sequence",
    "build_graph",
    "mix_weight_slots",
    "doubly_stochastic_matrix",
    "lambda2",
    "spectral_gap",
]


@dataclasses.dataclass(frozen=True)
class CommGraph:
    """A k-regular communication graph over n consensus nodes.

    Attributes:
      name: topology identifier.
      n: number of consensus nodes (paper: processors).
      shifts: circulant shift set S (each s in S contributes edges i -> i+s
        mod n AND i -> i-s mod n unless s == n-s mod n). For non-circulant
        graphs `shifts` is None and `edges` carries an explicit permutation
        list instead.
      perms: list of permutations (each a tuple of length n, perm[i] = the
        node whose value node i RECEIVES). Every mixing round applies each
        permutation once -- this is exactly the ppermute source list.
      self_weight / edge_weight: lazy uniform mixing weights; P = sw*I on the
        diagonal and ew per received message.
    """

    name: str
    n: int
    perms: tuple[tuple[int, ...], ...]
    self_weight: float
    edge_weight: float

    @property
    def degree(self) -> int:
        return len(self.perms)

    @property
    def k(self) -> int:  # paper notation
        return self.degree

    def mixing_matrix(self) -> np.ndarray:
        """Doubly-stochastic P (host-side oracle, used for analysis/tests)."""
        n = self.n
        P = np.eye(n) * self.self_weight
        for perm in self.perms:
            for i in range(n):
                P[i, perm[i]] += self.edge_weight
        return P

    def lambda2(self) -> float:
        return lambda2(self.mixing_matrix())

    def spectral_gap(self) -> float:
        return 1.0 - math.sqrt(max(self.lambda2(), 0.0))

    def ppermute_pairs(self) -> tuple[tuple[tuple[int, int], ...], ...]:
        """Per-edge (source, destination) pairs for jax.lax.ppermute.

        ppermute takes [(src, dst), ...]; node dst receives from src. Our
        perms store perm[i] = src for receiver i.
        """
        out = []
        for perm in self.perms:
            out.append(tuple((int(perm[i]), int(i)) for i in range(self.n)))
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class GraphSequence:
    """Time-varying topology: a periodic sequence of same-n graphs.

    The paper's analysis fixes G, but its cluster motivation (and the
    Yarmoshik-Klimenko time-varying lower bound in PAPERS.md) concerns
    networks whose edge set changes over time. `at(idx)` returns the graph
    active for the idx-th epoch (the netsim rewires every `rewire_every`
    sim-time units); B-connectedness holds trivially since every member is
    itself connected.
    """

    graphs: tuple[CommGraph, ...]

    def __post_init__(self):
        if not self.graphs:
            raise ValueError("GraphSequence needs at least one graph")
        sizes = {g.n for g in self.graphs}
        if len(sizes) != 1:
            raise ValueError(f"all graphs must share n, got {sorted(sizes)}")

    @property
    def n(self) -> int:
        return self.graphs[0].n

    def __len__(self) -> int:
        return len(self.graphs)

    def at(self, idx: int) -> CommGraph:
        return self.graphs[idx % len(self.graphs)]

    def lambda2_worst(self) -> float:
        """Pessimistic per-round mixing rate: max over the sequence (each
        round contracts disagreement by at most sqrt(lambda2) of the graph
        active that round)."""
        return max(g.lambda2() for g in self.graphs)


def expander_sequence(n: int, k: int = 4, length: int = 4,
                      seed: int = 0) -> GraphSequence:
    """`length` independently-rewired random k-regular expanders. Each draw
    is near-Ramanujan, so the sequence keeps a constant spectral gap while
    the edge set changes completely between epochs."""
    return GraphSequence(tuple(
        random_regular_expander(n, k=k, seed=seed + i) for i in range(length)))


def _circulant_perms(n: int, shifts: Sequence[int]) -> tuple[tuple[int, ...], ...]:
    """Each shift s gives a permutation perm[i] = (i - s) mod n, i.e. node i
    receives the value of node i-s (value travels +s around the ring)."""
    perms = []
    for s in shifts:
        s = s % n
        if s == 0:
            continue
        perms.append(tuple((i - s) % n for i in range(n)))
    return tuple(perms)


def _lazy_weights(k: int) -> tuple[float, float]:
    """Uniform max-degree weights: self 1/(k+1), each neighbor 1/(k+1)."""
    return 1.0 / (k + 1), 1.0 / (k + 1)


def complete_graph(n: int) -> CommGraph:
    """All-pairs communication. k = n-1, lambda_2 = 0 (exact average each
    round). Maps to an all-reduce (psum) on device rather than n-1 permutes;
    `consensus.py` special-cases it."""
    if n < 1:
        raise ValueError("n must be >= 1")
    perms = _circulant_perms(n, range(1, n))
    sw, ew = 1.0 / n, 1.0 / n
    return CommGraph("complete", n, perms, sw, ew)


def ring_graph(n: int) -> CommGraph:
    """Bidirectional ring: k=2 (k=1 for n=2). Worst-case expander; spectral
    gap O(1/n^2). Included as the pessimistic baseline topology."""
    if n < 2:
        raise ValueError("ring needs n >= 2")
    shifts = [1] if n == 2 else [1, n - 1]
    perms = _circulant_perms(n, shifts)
    sw, ew = _lazy_weights(len(perms))
    return CommGraph("ring", n, perms, sw, ew)


def torus_graph(n: int) -> CommGraph:
    """2D torus ring-of-rings: requires n = a*b with a = isqrt(n). k=4.
    Matches physical ICI torus wiring. Spectral gap O(1/n)."""
    a = int(math.isqrt(n))
    if a * a != n:
        raise ValueError(f"torus needs a square n, got {n}")
    if a < 3:
        return ring_graph(n)
    # shifts +-1 (row ring) and +-a (column ring) on the flattened index.
    perms = _circulant_perms(n, [1, n - 1, a, n - a])
    sw, ew = _lazy_weights(len(perms))
    return CommGraph("torus", n, perms, sw, ew)


def hypercube_graph(n: int) -> CommGraph:
    """Boolean hypercube: n must be a power of two, k = log2(n). Gap is
    constant-ish (1 - lambda2 = 2/(k+1) with lazy weights). XOR edges are
    expressed as explicit permutations (not circulant)."""
    k = n.bit_length() - 1
    if 1 << k != n:
        raise ValueError(f"hypercube needs power-of-two n, got {n}")
    perms = []
    for b in range(k):
        perms.append(tuple(i ^ (1 << b) for i in range(n)))
    sw, ew = _lazy_weights(k)
    return CommGraph("hypercube", n, tuple(perms), sw, ew)


def kregular_expander(n: int, k: int = 4, seed: int = 0) -> CommGraph:
    """k-regular expander with n nodes (paper ref [1] uses zig-zag products;
    we use chordal circulant shifts which for random-ish shift sets achieve
    near-Ramanujan gaps and map to uniform ppermutes).

    Shifts are chosen deterministically (seeded) from distinct values in
    [1, n/2); each shift contributes 2 to the degree (s and n-s), so k must
    be even (or n=2). Verified in tests: spectral gap stays ~constant as n
    grows for fixed k, unlike the ring.
    """
    if n <= k:
        return complete_graph(n)
    if k % 2 != 0:
        raise ValueError("kregular_expander needs even k (circulant +-s pairs)")
    rng = np.random.default_rng(seed)
    # Greedy pick of k/2 distinct shifts maximizing the spectral gap of the
    # resulting circulant. Candidate pool: all shifts in [1, n//2].
    candidates = list(range(1, n // 2 + 1))
    chosen: list[int] = []
    need = k // 2
    # Start from shift 1 (keeps graph connected), then greedily add the shift
    # that maximizes the gap. For large n, sample candidates to keep it cheap.
    chosen.append(1)
    while len(chosen) < need:
        pool = candidates
        if len(pool) > 64:
            pool = sorted(rng.choice(candidates, size=64, replace=False).tolist())
        best_s, best_gap = None, -1.0
        for s in pool:
            if s in chosen:
                continue
            trial = chosen + [s]
            g = _circulant_gap(n, trial)
            if g > best_gap:
                best_gap, best_s = g, s
        chosen.append(int(best_s))
    shifts: list[int] = []
    for s in chosen:
        shifts.append(s)
        if (n - s) % n != s:
            shifts.append(n - s)
    perms = _circulant_perms(n, shifts)
    sw, ew = _lazy_weights(len(perms))
    return CommGraph(f"expander{k}", n, perms, sw, ew)


def _circulant_gap(n: int, half_shifts: Sequence[int]) -> float:
    """Spectral gap of the lazy circulant mixing matrix with +-s edges,
    computed via the DFT eigenvalues of a circulant (O(n * |S|))."""
    shifts = []
    for s in half_shifts:
        shifts.append(s % n)
        if (n - s) % n != s % n:
            shifts.append((n - s) % n)
    k = len(shifts)
    w = 1.0 / (k + 1)
    j = np.arange(n)
    lam = np.full(n, w, dtype=np.complex128)
    for s in shifts:
        lam += w * np.exp(2j * np.pi * j * s / n)
    mags = np.abs(lam)
    mags.sort()
    lam2 = mags[-2] if n > 1 else 0.0
    return 1.0 - math.sqrt(min(max(lam2, 0.0), 1.0))


def random_regular_expander(n: int, k: int = 4, seed: int = 0) -> CommGraph:
    """k-regular expander via the permutation model: union of k/2 random
    n-cycles and their inverses. Near-Ramanujan with high probability
    (lambda_2(A) ~ 2*sqrt(k-1)), so the spectral gap is INDEPENDENT of n --
    the property the paper's claim C3 needs. Unlike circulant chords these
    permutations are not uniform torus shifts; on real hardware each edge is
    still a single ppermute, but may traverse multiple ICI hops. Use
    `kregular_expander` (circulant) when n is small or locality matters, and
    this one when n grows past a few hundred nodes.
    """
    if n <= k:
        return complete_graph(n)
    if k % 2 != 0:
        raise ValueError("random_regular_expander needs even k")
    rng = np.random.default_rng(seed)
    perms: list[tuple[int, ...]] = []
    for _ in range(k // 2):
        order = rng.permutation(n)  # random n-cycle visiting `order`
        nxt = np.empty(n, dtype=np.int64)
        nxt[order] = np.roll(order, -1)  # successor along the cycle
        fwd = tuple(int(v) for v in nxt)
        inv = np.empty(n, dtype=np.int64)
        inv[nxt] = np.arange(n)
        bwd = tuple(int(v) for v in inv)
        perms.extend([fwd, bwd])
    sw, ew = _lazy_weights(len(perms))
    return CommGraph(f"rregular{k}", n, tuple(perms), sw, ew)


_BUILDERS = {
    "complete": complete_graph,
    "ring": ring_graph,
    "torus": torus_graph,
    "hypercube": hypercube_graph,
}


def build_graph(name: str, n: int, *, k: int = 4, seed: int = 0) -> CommGraph:
    """Factory: `name` in {complete, ring, torus, hypercube, expander}."""
    if name.startswith("rregular"):
        kk = int(name[len("rregular"):]) if len(name) > len("rregular") else k
        return random_regular_expander(n, k=kk, seed=seed)
    if name.startswith("expander"):
        kk = int(name[len("expander"):]) if len(name) > len("expander") else k
        return kregular_expander(n, k=kk, seed=seed)
    try:
        return _BUILDERS[name](n)
    except KeyError:
        raise ValueError(f"unknown graph {name!r}; have "
                         f"{sorted(_BUILDERS) + ['expander<k>']}") from None


def mix_weight_slots(W: np.ndarray, S_in: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Fold an (n, n) mixing-matrix override into per-slot edge weights.

    S_in is the (n, k) in-neighbor slot structure (S_in[i, j] = the node
    whose value node i receives in permutation slot j). W[i, src] is the
    TOTAL (i, src) pair weight, so a src occupying several slots
    contributes W / multiplicity per slot. Returns ((n, k) slot weights,
    (n,) self weights), both float64.

    This is THE definition of the reweighted-gossip slot convention: the
    dense simulator's sparse mix (`core.dda.DDASimulator`) and the netsim
    vectorized engine's stale mix both fold through here, which is what
    keeps `AdaptiveController(reweight_gossip=True)` runs comparable
    across execution modes (tests/test_kernels.py pins the convention
    against the dense-matmul oracle independently).
    """
    W = np.asarray(W, dtype=np.float64)
    n, k = S_in.shape
    mult = np.zeros((n, k), dtype=np.int64)
    for slot in range(k):
        mult[:, slot] = (S_in == S_in[:, slot][:, None]).sum(axis=1)
    rows = np.arange(n)[:, None]
    return W[rows, S_in] / mult, np.diag(W).copy()


def doubly_stochastic_matrix(graph: CommGraph) -> np.ndarray:
    return graph.mixing_matrix()


def lambda2(P: np.ndarray) -> float:
    """Second-largest eigenvalue magnitude of a doubly-stochastic P.

    Symmetric inputs (the lazy Metropolis weights, and Sinkhorn-rebalanced
    reweightings of them) take the `eigvalsh` fast path -- ~5x cheaper and
    numerically tighter, which matters to the online controller
    (`repro.adaptive`) refreshing lambda2 on every retune cadence rather
    than once per run. Non-symmetric matrices fall back to `eigvals`.
    """
    P = np.asarray(P, dtype=np.float64)
    if np.allclose(P, P.T, rtol=0.0, atol=1e-12):
        mags = np.abs(np.linalg.eigvalsh(P))
        mags.sort()
    else:
        mags = np.sort(np.abs(np.linalg.eigvals(P)))
    if len(mags) < 2:
        return 0.0
    return float(min(max(mags[-2], 0.0), 1.0))


def spectral_gap(P: np.ndarray) -> float:
    return 1.0 - math.sqrt(lambda2(P))
