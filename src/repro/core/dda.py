"""Distributed Dual Averaging (DDA) -- the paper's algorithm (eq. 3-5).

Per node i, at iteration t (1-indexed):

    z_i(t)   = sum_j p_ij z_j(t-1) + g_i(t-1)         (consensus + subgradient)
    x_i(t)   = argmin_x { <z_i(t), x> + psi(x)/a(t) } (proximal step)
    xhat_i(t)= ((t-1) xhat_i(t-1) + x_i(t)) / t       (running average)

with psi(x) = 0.5 ||x||^2 the proximal step is x = Proj_X(-a(t) z) (paper V.A).
On cheap iterations (no communication) the consensus sum is replaced by
z_i(t) = z_i(t-1) + g_i(t-1)  (paper IV.A).

Two execution modes:

  * `DDASimulator` -- stacked (n, ...) arrays on one device; mixing by dense
    P matmul. Bit-faithful to the paper's algorithm; used for the paper's
    experiments (benchmarks/fig*) and as the oracle for the distributed mode.
  * `dda_local_step` / `dda_mix_step` -- per-shard pytree updates with
    `mix_collective` over a mesh axis, used by the production launcher. Both
    are pure and jit/shard_map friendly; the schedule (which step type to run)
    is decided by the host launcher, never by traced control flow, so each
    variant compiles to a collective-free / collective-bearing program
    respectively.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus as _cons
from repro.core.graphs import CommGraph
from repro.core.schedules import CommSchedule, EveryIteration

__all__ = [
    "DDAState",
    "dda_init",
    "dda_local_step",
    "dda_mix_step",
    "DDASimulator",
    "SimTrace",
    "TRACE_FIELDS",
    "json_sanitize",
    "stepsize_sqrt",
    "trace_time_to_reach",
]

PyTree = Any


def stepsize_sqrt(A: float, q: float = 0.5) -> Callable[[jax.Array], jax.Array]:
    """a(t) = A / t^q (paper uses q=1/2 for bounded/periodic schedules and
    general q in (p, 1) for increasingly sparse ones).

    The one canonical definition of the default schedule, shared by every
    execution mode: the dense `DDASimulator` calls it with a traced float32
    scalar inside jit (jnp path), while `repro.netsim`'s event-driven nodes
    call it with host floats / float64 numpy batches (np path, full
    precision). Sharing the closure keeps stepsize sweeps comparable across
    modes -- a re-implemented inline lambda in one mode could silently
    diverge from the other.
    """
    def a(t):
        xp = jnp if isinstance(t, jax.Array) else np
        return A / xp.maximum(t, 1.0) ** q
    return a


class DDAState(NamedTuple):
    z: PyTree      # accumulated dual (subgradient) direction
    x: PyTree      # current primal iterate
    xhat: PyTree   # running average (the algorithm's output)
    t: jax.Array   # iteration counter (float32 scalar for stable division)


def dda_init(x0: PyTree) -> DDAState:
    zeros = jax.tree.map(jnp.zeros_like, x0)
    return DDAState(z=zeros, x=x0, xhat=x0, t=jnp.asarray(0.0, jnp.float32))


def _prox(z: PyTree, a_t: jax.Array, projection: Callable[[PyTree], PyTree] | None) -> PyTree:
    x = jax.tree.map(lambda zl: (-a_t * zl).astype(zl.dtype), z)
    return projection(x) if projection is not None else x


def _advance(state: DDAState, z_new: PyTree, a_fn, projection) -> DDAState:
    t_new = state.t + 1.0
    x_new = _prox(z_new, a_fn(t_new), projection)
    xhat_new = jax.tree.map(
        lambda h, x: (state.t * h + x) / t_new, state.xhat, x_new)
    return DDAState(z=z_new, x=x_new, xhat=xhat_new, t=t_new)


def dda_local_step(state: DDAState, grad: PyTree, a_fn,
                   projection: Callable | None = None) -> DDAState:
    """Cheap iteration: z <- z + g (no communication)."""
    z_new = jax.tree.map(jnp.add, state.z, grad)
    return _advance(state, z_new, a_fn, projection)


def dda_mix_step(state: DDAState, grad: PyTree, graph: CommGraph,
                 axis_name: str, a_fn,
                 projection: Callable | None = None) -> DDAState:
    """Expensive iteration: z <- P z + g (consensus + subgradient).

    Must be called inside shard_map with `axis_name` mapping the consensus
    axis (one DDA node per index).
    """
    mixed = _cons.tree_mix_collective(state.z, graph, axis_name)
    z_new = jax.tree.map(jnp.add, mixed, grad)
    return _advance(state, z_new, a_fn, projection)


# ---------------------------------------------------------------------------
# Single-process simulator (paper-faithful; stacked node dimension)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimTrace:
    """Evaluation trace with the paper's simulated time model attached."""

    iters: list[int]
    sim_time: list[float]       # cumulative time units: sum of 1/n + k r 1{comm}
    fvals: list[float]          # Fbar(t) = (1/n) sum_i F(xhat_i) (paper Fig 1/2)
    comms: list[int]            # cumulative communication rounds H_t
    disagreement: list[float]   # max_i ||z_i - z_bar||
    fvals_consensus: list[float] = dataclasses.field(default_factory=list)
    # F at the consensus average xhat_bar (not what the paper plots, but
    # useful to separate optimization error from network disagreement)


#: the canonical field list, derived from the dataclass so engine-equality
#: assertions and benchmark writers can never drift from SimTrace itself
TRACE_FIELDS = tuple(f.name for f in dataclasses.fields(SimTrace))


def json_sanitize(obj):
    """Strict-RFC JSON sanitizer for trace/result payloads: np scalars ->
    Python numbers, inf/nan -> null. A diverged or never-reached-target run
    is a legal result (tta = inf, blown-up fvals), and the files carrying
    it -- benchmark --out JSON, the convergence tier's failed-run artifacts
    -- must stay readable by jq/JSON.parse, which reject Infinity/NaN."""
    if isinstance(obj, dict):
        return {k: json_sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_sanitize(v) for v in obj]
    if isinstance(obj, (float, np.floating)):
        v = float(obj)
        return v if math.isfinite(v) else None
    if isinstance(obj, np.integer):
        return int(obj)
    return obj


def trace_time_to_reach(trace: SimTrace, eps_value: float,
                        use_consensus: bool = False) -> float:
    """First simulated time at which the objective reaches eps_value.

    Default (`use_consensus=False`) scans `trace.fvals`, i.e.
    Fbar(t) = (1/n) sum_i F(xhat_i) -- the per-node mean the paper's
    Fig. 1/2 time-to-accuracy curves are read from. Pass
    `use_consensus=True` to instead scan `trace.fvals_consensus`
    (F evaluated at the consensus average xhat_bar), which isolates
    optimization error from network disagreement. Shared by DDASimulator
    (simulated time axis) and netsim.NetSimulator (event-clock axis).
    """
    fvals = trace.fvals_consensus if use_consensus else trace.fvals
    for tt, fv in zip(trace.sim_time, fvals):
        if fv <= eps_value:
            return tt
    return float("inf")


class DDASimulator:
    """Runs DDA with n nodes as a stacked leading axis on one device.

    Args:
      subgrad_fn: (x_stack[n, ...], t) -> g_stack[n, ...]; node i's
        subgradient of f_i at x_i. Deterministic (batch) or stochastic.
      eval_fn: x[...] -> scalar F(x) on the FULL objective.
      graph: communication topology (mixing matrix P taken from it).
      schedule: communication schedule (every / periodic-h / sparse-p).
      a_fn: stepsize a(t).
      projection: optional Proj_X applied after the prox step (stacked).
      r: communication/computation tradeoff for the simulated time axis.
    """

    def __init__(self, subgrad_fn, eval_fn, graph: CommGraph,
                 schedule: CommSchedule | None = None,
                 a_fn=None, projection=None, r: float = 0.0,
                 compress_keep: float | None = None):
        self.subgrad_fn = subgrad_fn
        self.eval_fn = eval_fn
        self.graph = graph
        self.schedule = schedule or EveryIteration()
        self.a_fn = a_fn or stepsize_sqrt(1.0)
        self.projection = projection
        self.r = float(r)
        self.compress_keep = compress_keep
        self._P = jnp.asarray(graph.mixing_matrix(), jnp.float32)
        # off-diagonal mixing applies to RECEIVED (possibly compressed)
        # messages; the diagonal always uses the node's exact own state.
        self._P_off = self._P - jnp.diag(jnp.diag(self._P))
        self._P_diag = jnp.diag(self._P)

        def _mix(z, res):
            """One consensus round; top-k+error-feedback compression of the
            transmitted messages when compress_keep is set ([beyond paper],
            core/compression.py; reduces r by the compression ratio)."""
            if self.compress_keep is None:
                return _cons.mix_dense(z, self._P), res
            corrected = z + res
            k = max(1, int(corrected.shape[1] * self.compress_keep))
            mags = jnp.abs(corrected)
            thresh = jax.lax.top_k(mags, k)[0][:, -1:]  # kth largest per row
            sent = jnp.where(mags >= thresh, corrected, 0.0)
            new_res = corrected - sent
            mixed = (self._P_diag[:, None] * z
                     + _cons.mix_dense(sent, self._P_off))
            return mixed, new_res

        @jax.jit
        def _segment(z, x, xhat, res, t0, comm_mask, keys):
            """Scan `len(comm_mask)` iterations starting at t0 (0-indexed)."""
            def body(carry, inp):
                z, x, xhat, res, t = carry
                comm, key = inp
                g = self.subgrad_fn(x, t, key)
                z_mixed, res_new = jax.lax.cond(
                    comm, _mix, lambda zz, rr: (zz, rr), z, res)
                z_new = z_mixed + g
                t_new = t + 1.0
                a_t = self.a_fn(t_new)
                x_new = -a_t * z_new
                if self.projection is not None:
                    x_new = self.projection(x_new)
                xhat_new = (t * xhat + x_new) / t_new
                return (z_new, x_new, xhat_new, res_new, t_new), None

            (z, x, xhat, res, t), _ = jax.lax.scan(
                body, (z, x, xhat, res, t0), (comm_mask, keys))
            return z, x, xhat, res, t

        self._segment = _segment

    def run(self, x0_stack: jax.Array, T: int, eval_every: int = 25,
            seed: int = 0) -> SimTrace:
        n = self.graph.n
        assert x0_stack.shape[0] == n, "x0 must be stacked (n, ...)"
        z = jnp.zeros_like(x0_stack)
        x = x0_stack
        xhat = x0_stack
        res = jnp.zeros_like(x0_stack)
        t = jnp.asarray(0.0, jnp.float32)
        k = self.graph.degree
        trace = SimTrace([], [], [], [], [])
        sim_time = 0.0
        comm_total = 0
        root = jax.random.PRNGKey(seed)

        done = 0
        while done < T:
            seg = min(eval_every, T - done)
            mask = np.array([self.schedule.is_comm_step(done + i + 1)
                             for i in range(seg)])
            keys = jax.random.split(jax.random.fold_in(root, done), seg)
            z, x, xhat, res, t = self._segment(
                z, x, xhat, res, t, jnp.asarray(mask), keys)
            done += seg
            n_comm = int(mask.sum())
            comm_total += n_comm
            sim_time += seg * (1.0 / n) + n_comm * k * self.r
            xbar = jnp.mean(xhat, axis=0)
            trace.iters.append(done)
            trace.sim_time.append(sim_time)
            trace.fvals.append(float(jnp.mean(jax.vmap(self.eval_fn)(xhat))))
            trace.fvals_consensus.append(float(self.eval_fn(xbar)))
            trace.comms.append(comm_total)
            trace.disagreement.append(float(_cons.disagreement(z)))
        return trace

    def time_to_reach(self, trace: SimTrace, eps_value: float,
                      use_consensus: bool = False) -> float:
        """See `trace_time_to_reach` (default reads Fbar, per the paper)."""
        return trace_time_to_reach(trace, eps_value, use_consensus)
