"""Distributed Dual Averaging (DDA) -- the paper's algorithm (eq. 3-5).

Per node i, at iteration t (1-indexed):

    z_i(t)   = sum_j p_ij z_j(t-1) + g_i(t-1)         (consensus + subgradient)
    x_i(t)   = argmin_x { <z_i(t), x> + psi(x)/a(t) } (proximal step)
    xhat_i(t)= ((t-1) xhat_i(t-1) + x_i(t)) / t       (running average)

with psi(x) = 0.5 ||x||^2 the proximal step is x = Proj_X(-a(t) z) (paper V.A).
On cheap iterations (no communication) the consensus sum is replaced by
z_i(t) = z_i(t-1) + g_i(t-1)  (paper IV.A).

Two execution modes:

  * `DDASimulator` -- stacked (n, ...) arrays on one device. Mixing is the
    dense P matmul oracle or, for k-regular graphs, the sparse fast path
    (neighbor-index gather + the fused `kernels.ops.gossip_gather_mix`
    accumulation, O(nkd) instead of O(n^2 d)); the whole run executes as
    ONE compiled scan over precomputed comm-mask data (see `run`), with
    `run_batch` vmapping sweep lanes. Bit-faithful to the paper's
    algorithm; used for the paper's experiments (benchmarks/fig*) and as
    the oracle for the distributed mode.
  * `dda_local_step` / `dda_mix_step` -- per-shard pytree updates with
    `mix_collective` over a mesh axis, used by the production launcher. Both
    are pure and jit/shard_map friendly; the schedule (which step type to run)
    is decided by the host launcher, never by traced control flow, so each
    variant compiles to a collective-free / collective-bearing program
    respectively.
"""

from __future__ import annotations

import dataclasses
import math
import time
from functools import partial
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus as _cons
from repro.core.graphs import CommGraph
from repro.core.schedules import CommSchedule, EveryIteration

__all__ = [
    "DDAState",
    "dda_init",
    "dda_local_step",
    "dda_mix_step",
    "DDASimulator",
    "SimTrace",
    "TRACE_FIELDS",
    "json_sanitize",
    "stepsize_sqrt",
    "trace_time_to_reach",
]

PyTree = Any


def stepsize_sqrt(A: float, q: float = 0.5) -> Callable[[jax.Array], jax.Array]:
    """a(t) = A / t^q (paper uses q=1/2 for bounded/periodic schedules and
    general q in (p, 1) for increasingly sparse ones).

    The one canonical definition of the default schedule, shared by every
    execution mode: the dense `DDASimulator` calls it with a traced float32
    scalar inside jit (jnp path), while `repro.netsim`'s event-driven nodes
    call it with host floats / float64 numpy batches (np path, full
    precision). Sharing the closure keeps stepsize sweeps comparable across
    modes -- a re-implemented inline lambda in one mode could silently
    diverge from the other.
    """
    def a(t):
        xp = jnp if isinstance(t, jax.Array) else np
        return A / xp.maximum(t, 1.0) ** q
    return a


class DDAState(NamedTuple):
    z: PyTree      # accumulated dual (subgradient) direction
    x: PyTree      # current primal iterate
    xhat: PyTree   # running average (the algorithm's output)
    t: jax.Array   # iteration counter (float32 scalar for stable division)


def dda_init(x0: PyTree) -> DDAState:
    zeros = jax.tree.map(jnp.zeros_like, x0)
    return DDAState(z=zeros, x=x0, xhat=x0, t=jnp.asarray(0.0, jnp.float32))


def _prox(z: PyTree, a_t: jax.Array, projection: Callable[[PyTree], PyTree] | None) -> PyTree:
    x = jax.tree.map(lambda zl: (-a_t * zl).astype(zl.dtype), z)
    return projection(x) if projection is not None else x


def _advance(state: DDAState, z_new: PyTree, a_fn, projection) -> DDAState:
    t_new = state.t + 1.0
    x_new = _prox(z_new, a_fn(t_new), projection)
    xhat_new = jax.tree.map(
        lambda h, x: (state.t * h + x) / t_new, state.xhat, x_new)
    return DDAState(z=z_new, x=x_new, xhat=xhat_new, t=t_new)


def dda_local_step(state: DDAState, grad: PyTree, a_fn,
                   projection: Callable | None = None) -> DDAState:
    """Cheap iteration: z <- z + g (no communication)."""
    z_new = jax.tree.map(jnp.add, state.z, grad)
    return _advance(state, z_new, a_fn, projection)


def dda_mix_step(state: DDAState, grad: PyTree, graph: CommGraph,
                 axis_name: str, a_fn,
                 projection: Callable | None = None) -> DDAState:
    """Expensive iteration: z <- P z + g (consensus + subgradient).

    Must be called inside shard_map with `axis_name` mapping the consensus
    axis (one DDA node per index).
    """
    mixed = _cons.tree_mix_collective(state.z, graph, axis_name)
    z_new = jax.tree.map(jnp.add, mixed, grad)
    return _advance(state, z_new, a_fn, projection)


# ---------------------------------------------------------------------------
# Single-process simulator (paper-faithful; stacked node dimension)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimTrace:
    """Evaluation trace with the paper's simulated time model attached."""

    iters: list[int]
    sim_time: list[float]       # cumulative time units: sum of 1/n + k r 1{comm}
    fvals: list[float]          # Fbar(t) = (1/n) sum_i F(xhat_i) (paper Fig 1/2)
    comms: list[int]            # cumulative communication rounds H_t
    disagreement: list[float]   # max_i ||z_i - z_bar||
    fvals_consensus: list[float] = dataclasses.field(default_factory=list)
    # F at the consensus average xhat_bar (not what the paper plots, but
    # useful to separate optimization error from network disagreement)


#: the canonical field list, derived from the dataclass so engine-equality
#: assertions and benchmark writers can never drift from SimTrace itself
TRACE_FIELDS = tuple(f.name for f in dataclasses.fields(SimTrace))


def json_sanitize(obj):
    """Strict-RFC JSON sanitizer for trace/result payloads: np scalars ->
    Python numbers, inf/nan -> null. A diverged or never-reached-target run
    is a legal result (tta = inf, blown-up fvals), and the files carrying
    it -- benchmark --out JSON, the convergence tier's failed-run artifacts
    -- must stay readable by jq/JSON.parse, which reject Infinity/NaN."""
    if isinstance(obj, dict):
        return {k: json_sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_sanitize(v) for v in obj]
    if isinstance(obj, (float, np.floating)):
        v = float(obj)
        return v if math.isfinite(v) else None
    if isinstance(obj, np.integer):
        return int(obj)
    return obj


def trace_time_to_reach(trace: SimTrace, eps_value: float,
                        use_consensus: bool = False) -> float:
    """First simulated time at which the objective reaches eps_value.

    Default (`use_consensus=False`) scans `trace.fvals`, i.e.
    Fbar(t) = (1/n) sum_i F(xhat_i) -- the per-node mean the paper's
    Fig. 1/2 time-to-accuracy curves are read from. Pass
    `use_consensus=True` to instead scan `trace.fvals_consensus`
    (F evaluated at the consensus average xhat_bar), which isolates
    optimization error from network disagreement. Shared by DDASimulator
    (simulated time axis) and netsim.NetSimulator (event-clock axis).
    """
    fvals = trace.fvals_consensus if use_consensus else trace.fvals
    for tt, fv in zip(trace.sim_time, fvals):
        if fv <= eps_value:
            return tt
    return float("inf")


class DDASimulator:
    """Runs DDA with n nodes as a stacked leading axis on one device.

    Args:
      subgrad_fn: (x_stack[n, ...], t) -> g_stack[n, ...]; node i's
        subgradient of f_i at x_i. Deterministic (batch) or stochastic.
      eval_fn: x[...] -> scalar F(x) on the FULL objective. Must be
        jax-traceable: the default scanned loop evaluates the trace
        device-side (use `run(..., loop="segment")` for a host-only
        eval_fn).
      graph: communication topology (mixing matrix P taken from it).
      schedule: communication schedule (every / periodic-h / sparse-p).
      a_fn: stepsize a(t).
      projection: optional Proj_X applied after the prox step (stacked).
      r: communication/computation tradeoff for the simulated time axis.
      compression: a built `repro.compress.Compressor` (or None). The
        transmitted messages are compressed with error feedback kept in
        the scanned carry; sparsifiers (`topk`/`randk`) ride the fused
        compress-mix Pallas pass on the sparse path, quantizers ship a
        dequantized message stack through the same gather. The diagonal
        always mixes the node's exact own z -- only RECEIVED messages are
        compressed. `self.wire_ratio(d)` exposes the byte model for the
        effective tradeoff r -> r*c.
      compress_keep: legacy alias ([beyond paper], kept for back-compat):
        `compress_keep=f` is exactly `compression=TopK(keep=f)`. Mutually
        exclusive with `compression`.
      mix: "auto" | "dense" | "sparse" mixing realization. "dense" is the
        P @ z matmul oracle (the seed path; O(n^2 d)). "sparse" is the
        k-regular fast path: a neighbor-index gather + the fused
        `kernels.ops.gossip_gather_mix` accumulation (O(n k d)) -- the
        paper's degree-scaling communication argument applied to the
        simulator's own memory traffic. "auto" picks sparse whenever the
        graph's permutation edge set is materially sparser than complete
        (k + 1 < n) and any `mix_weights` override is supported on the
        edge set; it falls back to dense otherwise (the resolved choice
        is exposed as `self.mix_mode`). Compression no longer disqualifies
        the sparse path: compressed messages ride the fused compress-mix
        kernel (`kernels.ops.compress_mix`) there.
      mix_weights: optional (n, n) mixing-matrix override (e.g. the
        straggler-reweighted effective P from
        `AdaptiveController(reweight_gossip=True)`). The sparse path folds
        it into per-edge weight vectors (slot weight W[i, src] /
        multiplicity, the netsim engines' convention); a matrix with
        weight OUTSIDE the graph's edge-plus-diagonal support cannot be
        gathered along edges, so it automatically falls back to the dense
        matmul ("non-regular" in the kernel's sense).
    """

    def __init__(self, subgrad_fn, eval_fn, graph: CommGraph,
                 schedule: CommSchedule | None = None,
                 a_fn=None, projection=None, r: float = 0.0,
                 compress_keep: float | None = None,
                 mix: str = "auto",
                 mix_weights: np.ndarray | None = None,
                 compression=None):
        self.subgrad_fn = subgrad_fn
        self.eval_fn = eval_fn
        self.graph = graph
        self.schedule = schedule or EveryIteration()
        self.a_fn = a_fn or stepsize_sqrt(1.0)
        self.projection = projection
        self.r = float(r)
        if compress_keep is not None and compression is not None:
            raise ValueError("pass either compression or the legacy "
                             "compress_keep alias, not both")
        if compress_keep is not None:
            from repro.compress import TopK
            compression = TopK(keep=float(compress_keep))
        self.compress_keep = compress_keep
        # "none" normalizes to no compression so the uncompressed program
        # (and its compile cache keys) is byte-for-byte the seed program
        if compression is not None and compression.kind == "none":
            compression = None
        self.compression = compression
        self.mix_weights = (None if mix_weights is None
                            else np.asarray(mix_weights, np.float64))
        self.mix_mode = self._resolve_mix_mode(mix)
        #: per-segment mean per-node error-feedback residual norms of the
        #: last run/run_batch (np (S,) or (B, S)); zeros when uncompressed
        self.last_res_norms: np.ndarray | None = None
        P_host = (self.mix_weights if self.mix_weights is not None
                  else graph.mixing_matrix())
        self._P = jnp.asarray(P_host, jnp.float32)
        # off-diagonal mixing applies to RECEIVED (possibly compressed)
        # messages; the diagonal always uses the node's exact own state.
        self._P_off = self._P - jnp.diag(jnp.diag(self._P))
        self._P_diag = jnp.diag(self._P)
        if self.mix_mode == "sparse":
            S_in, w_self, w_edge = self._sparse_weights()
            self._S_in = jnp.asarray(S_in)
            self._w_self = jnp.asarray(w_self, jnp.float32)
            self._w_edge = jnp.asarray(w_edge, jnp.float32)

        def _mix(z, res, t):
            """One consensus round; messages are compressed (with the
            error-feedback residual `res` folded in and updated) when a
            compressor is attached ([beyond paper], repro.compress; the
            wire ratio c scales the effective tradeoff r -> r*c)."""
            comp = self.compression
            if self.mix_mode == "sparse":
                from repro.kernels import ops as _kops
                if comp is None:
                    return _kops.gossip_gather_mix_impl(
                        z, self._S_in, self._w_self, self._w_edge), res
                corrected = z + res
                if comp.is_sparsifier:
                    # fused sparsify-mix: the 0/1 support rides the kernel,
                    # never materializing the masked message stack
                    mask = comp.support_mask_jax(corrected, t)
                    mixed = _kops.compress_mix_impl(
                        z, corrected, mask, self._S_in, self._w_self,
                        self._w_edge)
                    sent = corrected * mask
                else:
                    sent = comp.compress_jax(corrected, t)
                    mixed = _kops.gossip_gather_mix_impl(
                        z, self._S_in, self._w_self, self._w_edge, msg=sent)
            else:
                if comp is None:
                    return _cons.mix_dense(z, self._P), res
                corrected = z + res
                sent = comp.compress_jax(corrected, t)
                # off-diagonal mixing consumes the TRANSMITTED messages;
                # the diagonal keeps the node's exact own z
                mixed = (self._P_diag[:, None] * z
                         + _cons.mix_dense(sent, self._P_off))
            new_res = corrected - sent if comp.error_feedback else res
            return mixed, new_res

        def make_body(always_comm: bool):
            """always_comm=True drops the per-iteration `lax.cond`: the
            host already knows the whole comm mask, and for an all-comm
            window the straight-line mix fuses into the z/x/xhat update
            chain (the cond boundary otherwise forces an extra
            materialization of the mixed z -- ~20% of the iteration on the
            CPU fast path)."""
            def body(carry, inp):
                z, x, xhat, res, t = carry
                comm, key = inp
                g = self.subgrad_fn(x, t, key)
                if always_comm:
                    z_mixed, res_new = _mix(z, res, t)
                else:
                    z_mixed, res_new = jax.lax.cond(
                        comm, _mix, lambda zz, rr, tt: (zz, rr), z, res, t)
                z_new = z_mixed + g
                t_new = t + 1.0
                a_t = self.a_fn(t_new)
                x_new = -a_t * z_new
                if self.projection is not None:
                    x_new = self.projection(x_new)
                xhat_new = (t * xhat + x_new) / t_new
                return (z_new, x_new, xhat_new, res_new, t_new), None
            return body

        body = make_body(always_comm=False)

        @jax.jit
        def _segment(z, x, xhat, res, t0, comm_mask, keys):
            """Scan `len(comm_mask)` iterations starting at t0 (0-indexed)."""
            (z, x, xhat, res, t), _ = jax.lax.scan(
                body, (z, x, xhat, res, t0), (comm_mask, keys))
            return z, x, xhat, res, t

        self._segment = _segment

        def make_scan_program(always_comm: bool):
            """Whole-run program: scan over evaluation segments, each an
            inner scan over iterations, with the trace statistics computed
            device-side -- ONE dispatch instead of T/eval_every, and the
            unit `run_batch` vmaps over sweep lanes.

            masks: (S, E) comm flags; starts: (S,) segment start iteration
            counts (the legacy per-segment RNG stream is reproduced by
            folding each start into `root`); root: run PRNGKey.
            """
            seg_body = make_body(always_comm)

            def prog(state, masks, starts, root):
                def seg(carry, inp):
                    mask, start = inp
                    keys = jax.random.split(jax.random.fold_in(root, start),
                                            mask.shape[0])
                    carry, _ = jax.lax.scan(seg_body, carry, (mask, keys))
                    z, x, xhat, res, t = carry
                    fv = jnp.mean(jax.vmap(self.eval_fn)(xhat))
                    fvc = self.eval_fn(jnp.mean(xhat, axis=0))
                    dis = _cons.disagreement(z)
                    # mean per-node error-feedback residual norm: the
                    # compression block's trajectory (zeros uncompressed)
                    rn = jnp.mean(jnp.sqrt(jnp.sum(
                        res.reshape(res.shape[0], -1) ** 2, axis=-1)))
                    return carry, (fv, fvc, dis, rn)

                return jax.lax.scan(seg, state, (masks, starts))
            return prog

        self._scan_programs = {ac: make_scan_program(ac)
                               for ac in (False, True)}
        self._scan_jits = {ac: jax.jit(p)
                           for ac, p in self._scan_programs.items()}
        self._scan_vmaps: dict[bool, Any] = {}  # built lazily by run_batch
        # AOT compile cache + per-run wall split (see _timed_call): keyed by
        # (program kind, argument shapes/dtypes); `last_timings` is reset at
        # the top of every run/run_batch and read by the experiments runner
        # to populate RunMetrics.compile_s / execute_s.
        self._compiled: dict[tuple, Any] = {}
        self.last_timings: dict[str, float] = {
            "compile_s": 0.0, "execute_s": 0.0, "eval_s": 0.0}

    # -- timed dispatch ------------------------------------------------------

    def _reset_timings(self) -> None:
        self.last_timings = {"compile_s": 0.0, "execute_s": 0.0,
                             "eval_s": 0.0}
        self.last_res_norms = None

    def wire_ratio(self, d: int) -> float:
        """Bytes-on-wire fraction c for a d-float message under the
        attached compressor (1.0 uncompressed) -- the multiplier for the
        paper's effective tradeoff r -> r*c."""
        return (1.0 if self.compression is None
                else self.compression.wire_ratio(int(d)))

    def _get_compiled(self, kind: tuple, jitfn, args: tuple):
        """AOT executable for `jitfn` at these argument shapes, or None when
        `jitfn` has no `.lower` (e.g. a test double swapped in for a jit
        function -- callers then dispatch the object directly).

        `jitfn.lower(*args).compile()` produces the same XLA executable the
        plain jit call would run (bit-identical outputs), so splitting the
        wall here cannot perturb results. The executable is cached on
        (kind, arg shapes/dtypes) and the compile wall charged to
        `last_timings["compile_s"]` exactly once per shape -- which is what
        makes the cache shareable: a long-lived holder of this simulator
        (the serving layer's compile cache, the adaptive chunk loop) pays
        compile once and every later dispatch is pure execute."""
        if not hasattr(jitfn, "lower"):
            return None
        key = kind + tuple((tuple(leaf.shape), str(leaf.dtype))
                           for leaf in jax.tree_util.tree_leaves(args))
        entry = self._compiled.get(key)
        if entry is None:
            t0 = time.perf_counter()
            entry = jitfn.lower(*args).compile()
            self.last_timings["compile_s"] += time.perf_counter() - t0
            self._compiled[key] = entry
        return entry

    def _timed_call(self, kind: tuple, jitfn, args: tuple):
        """Dispatch a jitted program through the AOT lower/compile path so
        compile and execute walls are observable separately (see
        `_get_compiled`); the execute wall is charged to
        `last_timings["execute_s"]`."""
        entry = self._get_compiled(kind, jitfn, args)
        fn = jitfn if entry is None else entry
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        self.last_timings["execute_s"] += time.perf_counter() - t0
        return out

    # -- mix-mode resolution -------------------------------------------------

    def _resolve_mix_mode(self, mix: str) -> str:
        if mix not in ("auto", "dense", "sparse"):
            raise ValueError(f"mix must be auto/dense/sparse, got {mix!r}")
        if mix == "dense":
            return "dense"
        # NOTE: compression deliberately does NOT appear here anymore --
        # compressed messages ride the fused compress-mix kernel (or the
        # msg= gather for quantizers) on the sparse path.
        reasons = []
        if not self.graph.perms:
            reasons.append("graph has no permutation edge set")
        elif self.graph.degree + 1 >= self.graph.n:
            reasons.append("graph is (near-)complete: the matmul moves "
                           "less memory than a degree-(n-1) gather")
        if self.mix_weights is not None and not self._edge_supported():
            reasons.append("mix_weights has weight outside the graph's "
                           "edge support (non-regular P)")
        if reasons:
            if mix == "sparse":
                raise ValueError("sparse mix unavailable: "
                                 + "; ".join(reasons))
            return "dense"
        return "sparse"

    def _edge_supported(self) -> bool:
        """True if mix_weights only places weight on self-loops + edges."""
        W = self.mix_weights
        n = self.graph.n
        allowed = np.eye(n, dtype=bool)
        for perm in self.graph.perms:
            allowed[np.arange(n), np.asarray(perm)] = True
        return not np.any((W != 0.0) & ~allowed)

    def _sparse_weights(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(S_in, w_self, w_edge) for the gather path. S_in[i, j] is the
        node whose value node i receives in permutation slot j. A
        `mix_weights` override folds through the shared
        `graphs.mix_weight_slots` convention (W[i, src] / multiplicity per
        slot), keeping dense and netsim reweighted gossip comparable."""
        g = self.graph
        S_in = np.stack([np.asarray(p, dtype=np.int64) for p in g.perms],
                        axis=1)  # (n, k)
        if self.mix_weights is None:
            # scalar weights: the op's uniform path scales the SUM of the
            # gathers once instead of broadcasting k weight columns
            return (S_in, np.float32(g.self_weight),
                    np.float32(g.edge_weight))
        from repro.core.graphs import mix_weight_slots
        w_slot, w_self = mix_weight_slots(self.mix_weights, S_in)
        return (S_in, w_self.astype(np.float32),
                w_slot.astype(np.float32))

    # -- run loops -----------------------------------------------------------

    def run(self, x0_stack: jax.Array, T: int, eval_every: int = 25,
            seed: int = 0, loop: str = "scan") -> SimTrace:
        """Run T iterations, evaluating every `eval_every`.

        loop="scan" (default): the whole run is one compiled program per
        distinct segment length (at most two: the full segments and a
        remainder), with the comm pattern precomputed host-side by
        `CommSchedule.comm_mask` and fed as data. loop="segment" keeps the
        legacy host loop -- one dispatch per evaluation segment with the
        trace statistics computed eagerly -- for host-only eval_fns and as
        the seed baseline `benchmarks/bench_dense.py` times against.
        """
        n = self.graph.n
        assert x0_stack.shape[0] == n, "x0 must be stacked (n, ...)"
        if loop == "segment":
            return self._run_segment_loop(x0_stack, T, eval_every, seed)
        if loop != "scan":
            raise ValueError(f"loop must be 'scan' or 'segment', got {loop!r}")
        self._reset_timings()
        mask_full = np.asarray(self.schedule.comm_mask(0, T), dtype=bool)
        ac = bool(mask_full.all())
        prog = self._scan_jits[ac]
        state = (jnp.zeros_like(x0_stack), x0_stack, x0_stack,
                 jnp.zeros_like(x0_stack), jnp.asarray(0.0, jnp.float32))
        root = jax.random.PRNGKey(seed)
        S, rem = divmod(T, eval_every)
        outs = []
        if S:
            masks = jnp.asarray(mask_full[:S * eval_every]
                                .reshape(S, eval_every))
            starts = jnp.asarray(np.arange(S, dtype=np.int32) * eval_every)
            state, out = self._timed_call(("scan", ac), prog,
                                          (state, masks, starts, root))
            outs.append(out)
        if rem:
            masks = jnp.asarray(mask_full[S * eval_every:].reshape(1, rem))
            starts = jnp.asarray(np.array([S * eval_every], dtype=np.int32))
            state, out = self._timed_call(("scan", ac), prog,
                                          (state, masks, starts, root))
            outs.append(out)
        if not outs:  # T == 0: an empty trace, as the legacy loop returns
            return SimTrace([], [], [], [], [])
        fv, fvc, dis, rn = (np.concatenate([np.asarray(o[i]) for o in outs])
                            for i in range(4))
        self.last_res_norms = rn
        # compressed messages are cheaper on the wire: the time axis charges
        # the effective tradeoff r*c (c == 1.0 leaves seeds bit-identical)
        r_eff = self.r * self.wire_ratio(int(np.prod(x0_stack.shape[1:])))
        return self._assemble_trace(mask_full, T, eval_every, r_eff,
                                    fv, fvc, dis)

    def _assemble_trace(self, mask_full, T, eval_every, r,
                        fv, fvc, dis) -> SimTrace:
        """Host bookkeeping: the simulated time axis (eq. 9 charges) from
        the precomputed comm mask, accumulated segment-by-segment in the
        exact float order of the legacy loop."""
        n, k = self.graph.n, self.graph.degree
        trace = SimTrace([], [], [], [], [])
        sim_time = 0.0
        comm_total = 0
        done = 0
        idx = 0
        while done < T:
            seg = min(eval_every, T - done)
            n_comm = int(mask_full[done:done + seg].sum())
            done += seg
            comm_total += n_comm
            sim_time += seg * (1.0 / n) + n_comm * k * r
            trace.iters.append(done)
            trace.sim_time.append(sim_time)
            trace.fvals.append(float(fv[idx]))
            trace.fvals_consensus.append(float(fvc[idx]))
            trace.comms.append(comm_total)
            trace.disagreement.append(float(dis[idx]))
            idx += 1
        return trace

    def _run_segment_loop(self, x0_stack, T, eval_every, seed) -> SimTrace:
        self._reset_timings()
        z = jnp.zeros_like(x0_stack)
        x = x0_stack
        xhat = x0_stack
        res = jnp.zeros_like(x0_stack)
        t = jnp.asarray(0.0, jnp.float32)
        n, k = self.graph.n, self.graph.degree
        r_eff = self.r * self.wire_ratio(int(np.prod(x0_stack.shape[1:])))
        trace = SimTrace([], [], [], [], [])
        sim_time = 0.0
        comm_total = 0
        root = jax.random.PRNGKey(seed)

        done = 0
        while done < T:
            seg = min(eval_every, T - done)
            mask = np.array([self.schedule.is_comm_step(done + i + 1)
                             for i in range(seg)])
            keys = jax.random.split(jax.random.fold_in(root, done), seg)
            z, x, xhat, res, t = self._timed_call(
                ("segment",), self._segment,
                (z, x, xhat, res, t, jnp.asarray(mask), keys))
            done += seg
            n_comm = int(mask.sum())
            comm_total += n_comm
            sim_time += seg * (1.0 / n) + n_comm * k * r_eff
            t_eval = time.perf_counter()
            xbar = jnp.mean(xhat, axis=0)
            trace.iters.append(done)
            trace.sim_time.append(sim_time)
            trace.fvals.append(float(jnp.mean(jax.vmap(self.eval_fn)(xhat))))
            trace.fvals_consensus.append(float(self.eval_fn(xbar)))
            trace.comms.append(comm_total)
            trace.disagreement.append(float(_cons.disagreement(z)))
            self.last_timings["eval_s"] += time.perf_counter() - t_eval
        return trace

    def run_batch(self, x0_stack: jax.Array, T: int, eval_every: int,
                  masks: np.ndarray, seeds: Sequence[int],
                  rs: Sequence[float] | None = None) -> list[SimTrace]:
        """Run B independent lanes of this simulator as ONE vmapped program.

        Lanes share the problem closures, graph, stepsize and iteration
        count but may differ in comm pattern (`masks`, shape (B, T) --
        sweep axes like `schedule.params.h` are just data here), RNG stream
        (`seeds`) and time charge (`rs`, host-side only). This is the
        executor behind `repro.experiments.run_sweep(parallel="vmap")`:
        one compile + one batched dispatch for a whole sweep grid instead
        of a compile per cell.
        """
        n = self.graph.n
        assert x0_stack.shape[0] == n, "x0 must be stacked (n, ...)"
        masks = np.asarray(masks, dtype=bool)
        B = masks.shape[0]
        assert masks.shape == (B, T), masks.shape
        assert len(seeds) == B, (len(seeds), B)
        c = self.wire_ratio(int(np.prod(x0_stack.shape[1:])))
        rs = ([self.r * c] * B if rs is None
              else [float(r) * c for r in rs])
        assert len(rs) == B

        self._reset_timings()
        ac = bool(masks.all())
        if ac not in self._scan_vmaps:
            self._scan_vmaps[ac] = jax.jit(jax.vmap(
                self._scan_programs[ac],
                in_axes=((0, 0, 0, 0, 0), 0, None, 0)))
        vprog = self._scan_vmaps[ac]
        tile = lambda a: jnp.broadcast_to(a, (B,) + a.shape)
        state = (tile(jnp.zeros_like(x0_stack)), tile(x0_stack),
                 tile(x0_stack), tile(jnp.zeros_like(x0_stack)),
                 jnp.zeros((B,), jnp.float32))
        roots = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
        S, rem = divmod(T, eval_every)
        outs = []
        if S:
            m = jnp.asarray(masks[:, :S * eval_every]
                            .reshape(B, S, eval_every))
            starts = jnp.asarray(np.arange(S, dtype=np.int32) * eval_every)
            state, out = self._timed_call(("vmap", ac), vprog,
                                          (state, m, starts, roots))
            outs.append(out)
        if rem:
            m = jnp.asarray(masks[:, S * eval_every:].reshape(B, 1, rem))
            starts = jnp.asarray(np.array([S * eval_every], dtype=np.int32))
            state, out = self._timed_call(("vmap", ac), vprog,
                                          (state, m, starts, roots))
            outs.append(out)
        if not outs:  # T == 0: empty traces, as the legacy loop returns
            return [SimTrace([], [], [], [], []) for _ in range(B)]
        fv, fvc, dis, rn = (np.concatenate([np.asarray(o[i]) for o in outs],
                                           axis=1) for i in range(4))
        self.last_res_norms = rn
        return [self._assemble_trace(masks[b], T, eval_every, rs[b],
                                     fv[b], fvc[b], dis[b])
                for b in range(B)]

    def time_to_reach(self, trace: SimTrace, eps_value: float,
                      use_consensus: bool = False) -> float:
        """See `trace_time_to_reach` (default reads Fbar, per the paper)."""
        return trace_time_to_reach(trace, eps_value, use_consensus)
