"""The communication/computation tradeoff r and its consequences.

Paper III.A time model (time normalized so ONE processor computes a gradient
on the FULL dataset in 1 unit):

    cost/iteration = 1/n + k*r                          (eq. 9)
    tau(eps)       = (C/eps)^2 * (1/n + k*r)            (eq. 10)
    n_opt (complete graph)           = 1/sqrt(r)        (eq. 11)
    h_opt (periodic, fixed n, G)     = sqrt(n k r / (18 + 12/(1-sqrt(lam2))))
                                                        (eq. 21)

r is a *measured* quantity: (time to transmit+receive one message) /
(time for one processor to compute a full-data gradient). On TPU we derive
both terms from the roofline of the compiled step:

    t_msg  = message_bytes / link_bw        (cross-consensus-axis transfer)
    t_grad = max(step_flops / peak_flops, step_bytes / hbm_bw) * n
             (local shard gradient time scaled back to full data)
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import schedules as _sched
from repro.core.graphs import lambda2 as _lambda2

__all__ = [
    "HardwareSpec",
    "TPU_V5E",
    "measure_r",
    "derive_r_from_roofline",
    "iteration_cost",
    "time_to_accuracy",
    "n_opt_complete",
    "h_opt",
    "predict_speedup",
    "ew_alpha",
    "ew_update",
    "lambda2_fast",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip peaks used in all roofline/tradeoff math (defaults: TPU v5e)."""

    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per ICI link
    dcn_bw: float = 25e9              # bytes/s cross-pod (per pod egress), assumed
    hbm_per_chip: float = 16e9        # bytes (v5e 16 GB)


TPU_V5E = HardwareSpec()


def measure_r(t_msg_seconds: float, t_full_grad_seconds: float) -> float:
    """Direct measurement, exactly as the paper does on its cluster:
    r = 0.85s / 29s = 0.0293 for full-MNIST metric learning (paper V.A)."""
    if t_full_grad_seconds <= 0:
        raise ValueError("gradient time must be positive")
    return t_msg_seconds / t_full_grad_seconds


def derive_r_from_roofline(
    message_bytes: float,
    local_step_flops: float,
    local_step_bytes: float,
    n: int,
    hw: HardwareSpec = TPU_V5E,
    *,
    link_bw: float | None = None,
    chips_per_node: int = 1,
) -> float:
    """Derive r for a consensus node that is itself a `chips_per_node`-chip
    synchronous group. `local_step_flops/bytes` are PER NODE per local step on
    its 1/n shard of the data; time for the full data on one node is n * that.
    """
    bw = link_bw if link_bw is not None else hw.dcn_bw
    t_msg = message_bytes / bw
    t_local = max(
        local_step_flops / (hw.peak_flops * chips_per_node),
        local_step_bytes / (hw.hbm_bw * chips_per_node),
    )
    t_full = t_local * n
    return t_msg / t_full


def iteration_cost(n: int, k: int, r: float, c: float = 1.0) -> float:
    """Time units per (expensive) iteration -- eq. (9).

    `c` is the bytes-on-wire compression ratio (`Compressor.wire_ratio`,
    1.0 uncompressed): compressed gossip transmits c of the bytes, so the
    per-message cost is r*c and every optimum below shifts as if the link
    were 1/c times faster. Kept as a separate knob (rather than folding
    into r at every call site) so predictions can quote both the raw and
    the effective tradeoff.
    """
    return 1.0 / n + k * r * c


def time_to_accuracy(
    eps: float,
    n: int,
    k: int,
    r: float,
    lam2: float,
    L: float = 1.0,
    R: float = 1.0,
    schedule: _sched.CommSchedule | None = None,
    c: float = 1.0,
) -> float:
    """tau(eps) in time units for a given topology + schedule.

    every-iteration: eq. (10);  periodic-h: eq. (20);  sparse-p: eq. (30/31).
    `c` is the compression byte ratio (effective per-message cost r*c, see
    `iteration_cost`); the convergence constants are UNCHANGED by c because
    error feedback keeps the transmitted averages unbiased -- compression
    only cheapens the wire term.
    """
    schedule = schedule or _sched.EveryIteration()
    C = schedule.constant(L, R, lam2)
    rc = r * c
    if isinstance(schedule, _sched.EveryIteration):
        T = (C / eps) ** 2
        return T * (1.0 / n + k * rc)
    if isinstance(schedule, _sched.Periodic):
        T = (C / eps) ** 2
        return T * (1.0 / n + k * rc / schedule.h)
    if isinstance(schedule, _sched.PiecewisePeriodic):
        # a spliced schedule's true tau is segment-dependent; quote the
        # pattern it is emitting NOW (h_current), consistent with
        # PiecewisePeriodic.constant -- this is the controller's working
        # prediction, refreshed every retune
        T = (C / eps) ** 2
        return T * (1.0 / n + k * rc / schedule.h_current)
    if isinstance(schedule, _sched.IncreasinglySparse):
        p = schedule.p
        if p >= 0.5:
            return math.inf  # outside the permissible range (paper IV.B)
        T = (C / eps) ** (2.0 / (1.0 - 2.0 * p))
        H = T ** (1.0 / (p + 1.0))
        return T / n + H * k * rc
    raise TypeError(f"unknown schedule type {type(schedule)}")


def n_opt_complete(r: float, c: float = 1.0) -> float:
    """Optimal processor count on the complete graph -- eq. (11), with the
    effective per-message cost r*c (compression enlarges the optimal
    cluster by 1/sqrt(c))."""
    if r * c <= 0:
        return math.inf
    return 1.0 / math.sqrt(r * c)


def h_opt(n: int, k: int, r: float, lam2: float, c: float = 1.0) -> float:
    """Optimal intercommunication interval -- eq. (21) with effective
    per-message cost r*c: cheaper messages pull h_opt back toward 1
    (communicate more often), by sqrt(c)."""
    gap = 1.0 - math.sqrt(min(max(lam2, 0.0), 1.0 - 1e-15))
    return math.sqrt(n * k * r * c / (18.0 + 12.0 / gap))


def h_opt_int(n: int, k: int, r: float, lam2: float, c: float = 1.0) -> int:
    """Integer interval: h is a count of iterations, so clamp to >= 1.
    Matches the paper's Fig. 2 reading of eq. (21): r=0.00089, n=10 complete
    graph gives h_opt < 1 -> 'h_opt = 1' (communicate every iteration)."""
    return max(1, round(h_opt(n, k, r, lam2, c)))


# ---------------------------------------------------------------------------
# Incremental refresh helpers (closed-loop controllers, repro.adaptive)
# ---------------------------------------------------------------------------

def ew_alpha(halflife: float) -> float:
    """Per-observation smoothing factor for an exponentially-weighted mean
    whose influence halves every `halflife` observations."""
    if halflife <= 0:
        raise ValueError("halflife must be positive")
    return 1.0 - 0.5 ** (1.0 / halflife)


def ew_update(mean: float, batch_mean: float, batch_count: int,
              alpha: float) -> float:
    """Fold a batch of `batch_count` observations (summarized by their mean)
    into a streaming EW mean in one step.

    Equivalent to `batch_count` sequential updates against the batch mean;
    against the individual values it differs only by the within-batch
    ordering weights, which is the right trade for the vectorized netsim
    engine (one update per event batch instead of one per message). A NaN
    `mean` means "no prior" and adopts the batch mean directly.
    """
    if batch_count <= 0:
        return mean
    if math.isnan(mean):
        return batch_mean
    w = 1.0 - (1.0 - alpha) ** batch_count
    return (1.0 - w) * mean + w * batch_mean


def lambda2_fast(P) -> float:
    """Second-largest eigenvalue magnitude of a stochastic matrix -- alias
    of `core.graphs.lambda2`, which dispatches symmetric inputs to the
    `eigvalsh` fast path. Kept under the tradeoff namespace because it is
    the controller-facing half of the incremental r / lambda2 refresh API
    (`ew_update` + `lambda2_fast` -> `h_opt`)."""
    return _lambda2(P)


def predict_speedup(n: int, k: int, r: float, lam2: float,
                    L: float = 1.0, R: float = 1.0, eps: float = 0.1) -> float:
    """tau(eps; 1 node, no comm) / tau(eps; n nodes) under every-iteration."""
    tau1 = time_to_accuracy(eps, 1, 0, 0.0, 0.0, L, R)
    taun = time_to_accuracy(eps, n, k, r, lam2, L, R)
    return tau1 / taun
